//! Blocked, cache-tiled, multithreaded flash-SDPA kernel — the CPU mirror
//! of the Pallas kernel's grid/block structure (DESIGN.md §13).
//!
//! Every native attention path in the repo (Algorithm 2 in
//! [`super::linear`], the quadratic oracle's row partition in
//! [`super::quadratic`], and the incremental decode engine's cached-row
//! attend in [`super::incremental`]) funnels through this module, behind a
//! [`KernelConfig`].  Two implementations share one contract:
//!
//! * [`flash_sdpa_scalar`] — the original scalar, single-threaded,
//!   per-element loop.  Kept verbatim as the **oracle reference**: the
//!   equivalence suite and the CI perf gate compare the blocked kernel
//!   against it.
//! * [`flash_sdpa_blocked`] — key/value rows processed in fixed-size
//!   blocks of `block_m` rows (the Pallas `kv` grid axis), with
//!   vectorizer-friendly fixed-lane inner loops over the feature width
//!   `c` (f32 block math feeding the existing f64 online-softmax running
//!   state), query rows partitioned across the reusable scoped thread
//!   pool ([`crate::exec::shared_pool`]), and a precomputed per-block
//!   causal-visibility table so fully masked key blocks are never read.
//!
//! ## Row sources
//!
//! The blocked kernel reads k/v rows through
//! [`KvRowSource`] (see [`super::quant`]), so the *same*
//! tiled loop serves raw f32 matrices (zero-copy row borrows — the f32
//! path is bit-identical to a kernel hard-coded on slices) and the
//! quantized f16/bf16 feature caches (each visible row is dequantized on
//! the fly into O(c) per-thread scratch inside the key-block loop).
//! [`flash_sdpa_rows`] is the row-source entry point;
//! [`flash_sdpa_blocked`] wraps it for plain slices.
//!
//! ## Fused projection (DESIGN.md §18)
//!
//! [`KvRowSource::RawPose`] rows are *raw* k/v features plus poses
//! ([`super::projections::RawPoseKv`]): the kernel phi_k-projects each key
//! block on the fly into O(block_m * c) per-thread scratch — once per
//! (query chunk, key block) pair via the shared-coefficient pair
//! projection — so the m x c projected k~/v~ tensors of Algorithm 2 line 2
//! are never materialized.  [`flash_sdpa_fused`] additionally fuses the
//! query-side projection and the output unprojection into the same chunk
//! loop, taking raw (n x d) queries to raw (n x d) outputs with only
//! per-thread transients.  Because the per-row arithmetic sequence (block
//! order, lane math, online-softmax folds) and every projected value are
//! identical to the project-then-attend path, the fused output is
//! **bit-identical** to it for the same `(block_m, lanes)` — and therefore
//! inherits all of its equivalence guarantees below.
//!
//! ## Determinism
//!
//! For a fixed `(block_m, lanes)` the blocked kernel is **bit-stable
//! across thread counts**: threads partition *query rows*, and each row's
//! reduction order (key blocks in order, lanes chunked in fixed sizes,
//! rows within a block in order) is a pure function of the inputs — no
//! cross-thread reduction exists.  `threads` only changes wall-clock,
//! never output bits.  Changing `block_m` or `lanes` changes the rounding
//! order and may perturb outputs within the f32 noise floor (the
//! equivalence suite bounds it at 1e-5 against the scalar oracle).
//!
//! ## All-masked query rows (pinned behavior)
//!
//! A query row whose timestamp precedes every key (`tq[i] < tk[j]` for all
//! j) has an empty softmax: `l_i == 0`.  Both kernels define its output as
//! an exact **zero row** — never `0/0 = NaN`.  `tests/kernel_equivalence.rs`
//! pins this for both paths.

use std::cell::RefCell;

use crate::config::{default_workers, Method};
use crate::exec::{prefetch_read, run_chunked, SendPtr};
use crate::geometry::Pose;

use super::projections::{self as proj, RawPoseKv};
use super::quant::KvRowSource;

/// Query rows claimed per pool task: small enough to load-balance ragged
/// visibility masks, large enough to amortize the work-stealing counter.
/// Public because it is also the fused path's query-chunk size — each key
/// block is re-projected once per chunk, so `ceil(n / ROWS_PER_TASK)` is
/// the fused recompute factor that [`super::memmodel::linear_fused_bytes`]
/// and the `linear::FUSED_MAX_QUERY_ROWS` routing threshold reason about.
pub const ROWS_PER_TASK: usize = 8;

/// Configuration of the blocked flash kernel.  `Default` resolves the
/// `SE2ATTN_KERNEL_{BLOCK_M,LANES,THREADS}` environment overrides once
/// per process and otherwise uses `block_m = 64`, `lanes = 8`,
/// `threads =` [`default_workers`] — so every call site that does not
/// plumb an explicit config still agrees on one kernel shape (bit-stable
/// results between e.g. `linear::attention` and the incremental engine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Key/value rows per block (the Pallas `kv` block dimension).
    pub block_m: usize,
    /// f32 lanes in the fixed-lane inner loops (4, 8 or 16 — anything
    /// else is normalized to 8).
    pub lanes: usize,
    /// Worker threads the query-row partition may use (the calling
    /// thread counts as one; `threads - 1` come from the shared pool).
    pub threads: usize,
}

impl KernelConfig {
    pub const DEFAULT_BLOCK_M: usize = 64;
    pub const DEFAULT_LANES: usize = 8;

    /// Fully explicit config (tests and benches — no env, no host probing).
    pub fn fixed(block_m: usize, lanes: usize, threads: usize) -> KernelConfig {
        KernelConfig {
            block_m,
            lanes,
            threads,
        }
        .normalized()
    }

    /// The default shape with an explicit thread count (`0` = keep the
    /// default) — the CLI / `ServeConfig` plumbing entry point.
    pub fn with_threads(threads: usize) -> KernelConfig {
        let mut cfg = KernelConfig::default();
        if threads > 0 {
            cfg.threads = threads;
        }
        cfg.normalized()
    }

    /// Read `SE2ATTN_KERNEL_{BLOCK_M,LANES,THREADS}` (each optional) on
    /// top of the built-in defaults.  Called once per process by
    /// `Default`; call directly to re-read the environment.
    pub fn from_env() -> KernelConfig {
        let var = |name: &str, fallback: usize| -> usize {
            std::env::var(name)
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&v| v > 0)
                .unwrap_or(fallback)
        };
        KernelConfig {
            block_m: var("SE2ATTN_KERNEL_BLOCK_M", Self::DEFAULT_BLOCK_M),
            lanes: var("SE2ATTN_KERNEL_LANES", Self::DEFAULT_LANES),
            threads: var("SE2ATTN_KERNEL_THREADS", default_workers()),
        }
        .normalized()
    }

    /// Clamp to shapes the kernel supports (lanes ∈ {4, 8, 16}; at least
    /// one key row per block; 1..=32 threads).
    pub fn normalized(&self) -> KernelConfig {
        KernelConfig {
            block_m: self.block_m.max(1),
            lanes: match self.lanes {
                4 | 8 | 16 => self.lanes,
                _ => Self::DEFAULT_LANES,
            },
            threads: self.threads.clamp(1, 32),
        }
    }

    /// Transient bytes of one worker thread's scratch (scores block +
    /// f32 value-block accumulator + f64 running accumulator) — the
    /// per-thread term of the linear-memory claim.  Quantized row
    /// sources add two c-wide f32 dequantization buffers per thread
    /// ([`Self::scratch_bytes_per_thread_rows`]); either way the
    /// per-thread cost stays O(c), independent of the context length m.
    pub fn scratch_bytes_per_thread(&self, c: usize, m: usize) -> usize {
        let bm = self.block_m.max(1).min(m.max(1));
        bm * std::mem::size_of::<f64>()
            + c * std::mem::size_of::<f32>()
            + c * std::mem::size_of::<f64>()
    }

    /// [`Self::scratch_bytes_per_thread`] plus the k/v dequantization
    /// buffers a quantized row source needs (2 c-wide f32 rows).
    pub fn scratch_bytes_per_thread_rows(&self, c: usize, m: usize, quantized: bool) -> usize {
        self.scratch_bytes_per_thread(c, m)
            + if quantized {
                2 * c * std::mem::size_of::<f32>()
            } else {
                0
            }
    }

    /// Transient bytes of one worker thread's *fused-path* scratch
    /// (DESIGN.md §18): the per-chunk q~/o~ tiles, the per-block
    /// projected k~/v~ tiles, the pair-projection staging rows, the f32
    /// value-block accumulator, and the per-row online-softmax state.
    /// O(block_m * c) — constant in both n and m, so the fused path's
    /// entire transient footprint is per-thread scratch; no O(m c)
    /// projected tensor ever exists.  (The se2fourier quadrature scratch
    /// adds O(F), negligible next to c = (4F+2) d/6 and excluded here.)
    pub fn scratch_bytes_per_thread_fused(&self, c: usize, m: usize) -> usize {
        let bm = self.block_m.max(1).min(m.max(1));
        let chunk = ROWS_PER_TASK;
        // f64: block scores + per-row running (m, l) + per-row accumulators
        (bm + 2 * chunk + chunk * c) * std::mem::size_of::<f64>()
            // f32: q~/o~ chunk tiles, k~/v~ block tiles, k/v pair staging,
            // value-block accumulator, unproject staging
            + (2 * chunk * c + 2 * bm * c + 4 * c) * std::mem::size_of::<f32>()
    }

    /// One-shot startup auto-tuner: microbenchmark the blocked kernel
    /// over a small deterministic synthetic problem across the supported
    /// `{block_m, lanes}` grid and return the fastest shape, with
    /// `threads` resolved the same way [`Self::from_env`] resolves it.
    ///
    /// * **Cached per process** (`OnceLock`): every later call returns the
    ///   same config, so all call sites agree on one kernel shape and
    ///   outputs stay bit-stable within the process.
    /// * **Env-overridable**: a valid `SE2ATTN_KERNEL_{BLOCK_M,LANES,
    ///   THREADS}` pins that dimension — the sweep only explores the
    ///   unpinned ones, so operators can still force an exact shape.
    /// * **Determinism**: the tuner only selects *which* `(block_m,
    ///   lanes)` runs; for any fixed choice the kernel output is a pure
    ///   function of the inputs, so an autotuned run is bit-identical to
    ///   an explicit [`Self::fixed`] run with the same fields (pinned by
    ///   `autotuned_config_is_bit_identical_to_explicit`).
    ///
    /// Costs a few milliseconds, once; both the native backend and the
    /// `pjrt` stub consume the result through the shared tiling contract
    /// ([`crate::runtime::kernel_tiling`]).
    pub fn autotune() -> KernelConfig {
        static TUNED: std::sync::OnceLock<KernelConfig> = std::sync::OnceLock::new();
        *TUNED.get_or_init(|| {
            let pin = |name: &str| -> Option<usize> {
                std::env::var(name)
                    .ok()
                    .and_then(|v| v.trim().parse::<usize>().ok())
                    .filter(|&v| v > 0)
            };
            let block_ms: Vec<usize> = match pin("SE2ATTN_KERNEL_BLOCK_M") {
                Some(v) => vec![v],
                None => vec![16, 32, 64, 128],
            };
            let lane_set: Vec<usize> = match pin("SE2ATTN_KERNEL_LANES") {
                Some(v) => vec![v],
                None => vec![4, 8, 16],
            };
            let threads = pin("SE2ATTN_KERNEL_THREADS").unwrap_or_else(default_workers);

            // deterministic synthetic problem, sized so one sweep stays in
            // the low milliseconds but block_m up to 128 still tiles m
            let (n, m, c) = (64usize, 512usize, 64usize);
            let mut rng = crate::prng::Rng::new(0xA070_77E5);
            let gen = |rng: &mut crate::prng::Rng, len: usize| -> Vec<f32> {
                (0..len).map(|_| rng.normal() as f32).collect()
            };
            let q = gen(&mut rng, n * c);
            let k = gen(&mut rng, m * c);
            let v = gen(&mut rng, m * c);
            let tq: Vec<i32> = (0..n).map(|i| i as i32).collect();
            let tk: Vec<i32> = (0..m).map(|j| (j / 8) as i32).collect();
            let scale = 1.0 / (c as f64).sqrt();
            let mut out = vec![0.0f32; n * c];

            let mut best = KernelConfig::fixed(Self::DEFAULT_BLOCK_M, Self::DEFAULT_LANES, threads);
            let mut best_ns = f64::INFINITY;
            for &bm in &block_ms {
                for &lanes in &lane_set {
                    let cand = KernelConfig::fixed(bm, lanes, threads);
                    // best-of-two damps one-off scheduling noise; ties keep
                    // the earlier (smaller) shape, so selection is stable
                    let mut t_ns = f64::INFINITY;
                    for _ in 0..2 {
                        let t0 = std::time::Instant::now();
                        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut out, &cand);
                        t_ns = t_ns.min(t0.elapsed().as_nanos() as f64);
                    }
                    if t_ns < best_ns {
                        best_ns = t_ns;
                        best = cand;
                    }
                }
            }
            best
        })
    }
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        static AUTO: std::sync::OnceLock<KernelConfig> = std::sync::OnceLock::new();
        *AUTO.get_or_init(KernelConfig::from_env)
    }
}

// ---------------------------------------------------------------------------
// Scalar oracle
// ---------------------------------------------------------------------------

/// Streaming SDPA over projected tensors: q (n x c), k/v (m x c), online
/// softmax with visibility rule `tq >= tk`, O(c) transient state.  The
/// scalar, single-threaded oracle the blocked kernel is verified against;
/// an all-masked query row is a defined zero row.
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_scalar(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
) {
    let n = tq.len();
    let m = tk.len();
    debug_assert_eq!(q.len(), n * c, "q shape");
    debug_assert_eq!(k.len(), m * c, "k shape");
    debug_assert_eq!(v.len(), m * c, "v shape");
    debug_assert_eq!(out.len(), n * c, "out shape");
    let mut acc = vec![0.0f64; c];
    for i in 0..n {
        let qi = &q[i * c..(i + 1) * c];
        let mut m_i = f64::NEG_INFINITY;
        let mut l_i = 0.0f64;
        acc.iter_mut().for_each(|a| *a = 0.0);
        for j in 0..m {
            if tq[i] < tk[j] {
                continue;
            }
            let kj = &k[j * c..(j + 1) * c];
            let s: f64 = qi
                .iter()
                .zip(kj.iter())
                .map(|(a, b)| *a as f64 * *b as f64)
                .sum::<f64>()
                * scale;
            let m_new = m_i.max(s);
            let alpha = if m_i == f64::NEG_INFINITY {
                0.0
            } else {
                (m_i - m_new).exp()
            };
            let p = (s - m_new).exp();
            l_i = l_i * alpha + p;
            let vj = &v[j * c..(j + 1) * c];
            for (a, &vv) in acc.iter_mut().zip(vj.iter()) {
                *a = *a * alpha + p * vv as f64;
            }
            m_i = m_new;
        }
        let oi = &mut out[i * c..(i + 1) * c];
        if l_i > 0.0 {
            for (o, &a) in oi.iter_mut().zip(acc.iter()) {
                *o = (a / l_i) as f32;
            }
        } else {
            // all-masked query row: defined as zero, never 0/0 = NaN
            oi.iter_mut().for_each(|o| *o = 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked multithreaded kernel
// ---------------------------------------------------------------------------

/// Precomputed visibility envelope of one key block: with the rule
/// `visible(i, j) = tq[i] >= tk[j]`, a query with `tq < min_tk` sees
/// nothing in the block (skip — k/v rows never touched) and one with
/// `tq >= max_tk` sees everything (no per-row mask test in the hot loop).
#[derive(Clone, Copy, Debug)]
struct KeyBlock {
    start: usize,
    end: usize,
    min_tk: i32,
    max_tk: i32,
}

fn key_blocks(tk: &[i32], block_m: usize) -> Vec<KeyBlock> {
    let m = tk.len();
    let bm = block_m.max(1);
    let mut blocks = Vec::with_capacity(m.div_ceil(bm));
    let mut start = 0;
    while start < m {
        let end = (start + bm).min(m);
        let mut min_tk = i32::MAX;
        let mut max_tk = i32::MIN;
        for &t in &tk[start..end] {
            min_tk = min_tk.min(t);
            max_tk = max_tk.max(t);
        }
        blocks.push(KeyBlock {
            start,
            end,
            min_tk,
            max_tk,
        });
        start = end;
    }
    blocks
}

/// Per-thread scratch, reused across calls through a thread-local so pool
/// workers allocate once and keep their buffers warm.
#[derive(Default)]
struct RowScratch {
    /// Scores of one key block (f64 — the online-softmax state dtype).
    s: Vec<f64>,
    /// f32 block accumulator for `sum_j p_j * v_j` (the "f32 block math").
    vacc: Vec<f32>,
    /// f64 running output accumulator (carried across blocks).
    acc: Vec<f64>,
    /// Dequantization buffer for one key row (quantized sources only;
    /// stays empty on the f32 path, which borrows rows zero-copy).
    krow: Vec<f32>,
    /// Dequantization buffer for one value row (quantized sources only).
    vrow: Vec<f32>,
}

impl RowScratch {
    fn ensure(&mut self, block_m: usize, c: usize) {
        if self.s.len() < block_m {
            self.s.resize(block_m, 0.0);
        }
        if self.vacc.len() != c {
            self.vacc.resize(c, 0.0);
        }
        if self.acc.len() != c {
            self.acc.resize(c, 0.0);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<RowScratch> = RefCell::new(RowScratch::default());
}

/// Per-chunk profiling accumulator: plain register counters, incremented
/// unconditionally (the increments are free next to the row math) and
/// flushed to the global [`crate::trace::kernel_profile`] atomics once per
/// chunk *only* when profiling is enabled — the disabled path pays one
/// branch per chunk, nothing per row.
#[derive(Clone, Copy, Default)]
struct RowProfile {
    blocks_visited: u64,
    blocks_skipped: u64,
    k_rows_read: u64,
    v_rows_read: u64,
}

/// Fixed-lane dot product: L parallel f32 partial sums (vectorizer
/// fodder), combined left-to-right in f64, plus a scalar tail.  The
/// reduction order depends only on `L` and the slice length — never on
/// the executing thread.
#[inline]
fn dot_lanes<const L: usize>(a: &[f32], b: &[f32]) -> f64 {
    let chunks = a.len() / L;
    let mut acc = [0.0f32; L];
    for ch in 0..chunks {
        let ab = &a[ch * L..ch * L + L];
        let bb = &b[ch * L..ch * L + L];
        for l in 0..L {
            acc[l] += ab[l] * bb[l];
        }
    }
    let mut s = 0.0f64;
    for &x in acc.iter() {
        s += x as f64;
    }
    for t in chunks * L..a.len() {
        s += (a[t] * b[t]) as f64;
    }
    s
}

/// Fixed-lane `acc += x * v` over f32 (the value-block accumulation).
#[inline]
fn axpy_lanes<const L: usize>(acc: &mut [f32], x: f32, v: &[f32]) {
    let chunks = acc.len() / L;
    for ch in 0..chunks {
        let ab = &mut acc[ch * L..ch * L + L];
        let vb = &v[ch * L..ch * L + L];
        for l in 0..L {
            ab[l] += x * vb[l];
        }
    }
    for t in chunks * L..acc.len() {
        acc[t] += x * v[t];
    }
}

/// One query row against every key block: flash online softmax with one
/// rescale per *block* instead of per element.  `k`/`v` rows come
/// through a [`KvRowSource`]: borrowed zero-copy for f32 storage,
/// dequantized into the per-thread `sc.krow`/`sc.vrow` scratch for
/// quantized storage — the tiled loop is otherwise identical.
#[allow(clippy::too_many_arguments)]
fn attend_row<const L: usize>(
    qi: &[f32],
    k: &KvRowSource<'_>,
    v: &KvRowSource<'_>,
    tqi: i32,
    tk: &[i32],
    c: usize,
    scale: f64,
    blocks: &[KeyBlock],
    sc: &mut RowScratch,
    out_row: &mut [f32],
    prof: &mut RowProfile,
) {
    // split the scratch into disjoint field borrows once, so a row
    // dequantized into `krow` can be read while `s` is being written
    let RowScratch {
        s,
        vacc,
        acc,
        krow,
        vrow,
    } = sc;
    let mut m_i = f64::NEG_INFINITY;
    let mut l_i = 0.0f64;
    acc.iter_mut().for_each(|a| *a = 0.0);
    for b in blocks {
        if tqi < b.min_tk {
            // fully masked block: skipped before any k/v row is read
            prof.blocks_skipped += 1;
            continue;
        }
        prof.blocks_visited += 1;
        let fully_visible = tqi >= b.max_tk;
        // ---- scores (f32 lane math -> f64 block max) --------------------
        let mut bmax = f64::NEG_INFINITY;
        for (jj, j) in (b.start..b.end).enumerate() {
            s[jj] = if fully_visible || tqi >= tk[j] {
                prof.k_rows_read += 1;
                let kj = k.row(j, c, krow);
                let sv = dot_lanes::<L>(qi, kj) * scale;
                if sv > bmax {
                    bmax = sv;
                }
                sv
            } else {
                f64::NEG_INFINITY
            };
        }
        // tqi >= b.min_tk guarantees at least one visible key, so bmax is
        // finite and `alpha` below can never be exp(-inf - -inf) = NaN
        let m_new = if bmax > m_i { bmax } else { m_i };
        let alpha = (m_i - m_new).exp(); // m_i == -inf  =>  alpha == 0
        // ---- probabilities + f32 value-block accumulation ---------------
        vacc.iter_mut().for_each(|x| *x = 0.0);
        let mut l_b = 0.0f64;
        for (jj, j) in (b.start..b.end).enumerate() {
            let sv = s[jj];
            if sv == f64::NEG_INFINITY {
                continue;
            }
            let p = (sv - m_new).exp();
            l_b += p;
            prof.v_rows_read += 1;
            let vj = v.row(j, c, vrow);
            axpy_lanes::<L>(vacc, p as f32, vj);
        }
        // ---- fold the block into the f64 running state ------------------
        l_i = l_i * alpha + l_b;
        for (a, &vb) in acc.iter_mut().zip(vacc.iter()) {
            *a = *a * alpha + vb as f64;
        }
        m_i = m_new;
    }
    if l_i > 0.0 {
        for (o, &a) in out_row.iter_mut().zip(acc.iter()) {
            *o = (a / l_i) as f32;
        }
    } else {
        // all-masked query row: defined as zero, never 0/0 = NaN
        out_row.iter_mut().for_each(|o| *o = 0.0);
    }
}

// ---------------------------------------------------------------------------
// Fused projection driver (DESIGN.md §18)
// ---------------------------------------------------------------------------

/// Per-thread scratch of the fused driver: everything the chunk loop
/// touches, all O(block_m * c) or O(ROWS_PER_TASK * c) — the byte model
/// is [`KernelConfig::scratch_bytes_per_thread_fused`].
#[derive(Default)]
struct FusedScratch {
    /// Projected q~ tile of one chunk (ROWS_PER_TASK x c; only the
    /// fully fused entry point uses it — the row-source path reads the
    /// caller's already-projected queries).
    qt: Vec<f32>,
    /// Attended o~ tile of one chunk (fully fused entry point only).
    ot: Vec<f32>,
    /// Projected k~ rows of the current key block (block_m x c).
    kblock: Vec<f32>,
    /// Projected v~ rows of the current key block (block_m x c).
    vblock: Vec<f32>,
    /// Pair-projection staging row (also reused as the q-projection /
    /// o-unprojection staging row by the fully fused entry point).
    krow: Vec<f32>,
    vrow: Vec<f32>,
    /// Scores of one key block (f64 — the online-softmax state dtype).
    s: Vec<f64>,
    /// f32 value-block accumulator (shared across the chunk's rows —
    /// zeroed per (row, block) exactly as in [`attend_row`]).
    vacc: Vec<f32>,
    /// Per-row f64 running output accumulators (chunk x c).
    acc: Vec<f64>,
    /// Per-row running softmax max.
    mstate: Vec<f64>,
    /// Per-row running softmax normalizer.
    lstate: Vec<f64>,
    /// se2fourier quadrature scratch (built lazily, rebuilt when F
    /// changes between calls on this thread).
    se2f: Option<proj::Se2fKeyScratch>,
}

impl FusedScratch {
    fn ensure(&mut self, chunk: usize, block_m: usize, c: usize, kv: &RawPoseKv<'_>) {
        if self.s.len() < block_m {
            self.s.resize(block_m, 0.0);
        }
        if self.vacc.len() != c {
            self.vacc.resize(c, 0.0);
        }
        if self.kblock.len() < block_m * c {
            self.kblock.resize(block_m * c, 0.0);
        }
        if self.vblock.len() < block_m * c {
            self.vblock.resize(block_m * c, 0.0);
        }
        if self.acc.len() < chunk * c {
            self.acc.resize(chunk * c, 0.0);
        }
        if self.mstate.len() < chunk {
            self.mstate.resize(chunk, 0.0);
        }
        if self.lstate.len() < chunk {
            self.lstate.resize(chunk, 0.0);
        }
        if self.qt.len() < chunk * c {
            self.qt.resize(chunk * c, 0.0);
        }
        if self.ot.len() < chunk * c {
            self.ot.resize(chunk * c, 0.0);
        }
        if kv.method == Method::Se2Fourier
            && self.se2f.as_ref().map_or(false, |s| s.table.f != kv.fourier_f)
        {
            self.se2f = None;
        }
    }
}

thread_local! {
    static FUSED_SCRATCH: RefCell<FusedScratch> = RefCell::new(FusedScratch::default());
}

/// One chunk of query rows against every key block, with on-the-fly key
/// projection: each visited block's k/v rows are phi_k-projected **once
/// per chunk** into the per-thread `kblock`/`vblock` tiles (shared
/// Gamma/Lambda coefficients via [`RawPoseKv::project_pair_into`]), then
/// every row in the chunk runs *exactly* the [`attend_row`] block body
/// against the tile.  Per-row operation order and all operand values are
/// identical to the project-then-attend path, so outputs are
/// bit-identical to it; per-row state is carried in `mstate`/`lstate`/
/// `acc` across blocks instead of locals.
#[allow(clippy::too_many_arguments)]
fn attend_chunk_fused<const L: usize>(
    qt: &[f32],
    tq: &[i32],
    kv: &RawPoseKv<'_>,
    tk: &[i32],
    c: usize,
    scale: f64,
    blocks: &[KeyBlock],
    sc: &mut FusedScratch,
    ot: &mut [f32],
    prof: &mut RowProfile,
) {
    let chunk = tq.len();
    let FusedScratch {
        kblock,
        vblock,
        krow,
        vrow,
        s,
        vacc,
        acc,
        mstate,
        lstate,
        se2f,
        ..
    } = sc;
    for x in &mut mstate[..chunk] {
        *x = f64::NEG_INFINITY;
    }
    for x in &mut lstate[..chunk] {
        *x = 0.0;
    }
    acc[..chunk * c].iter_mut().for_each(|a| *a = 0.0);
    let chunk_max_tq = tq.iter().copied().max().unwrap_or(i32::MIN);
    for (bi, b) in blocks.iter().enumerate() {
        if chunk_max_tq < b.min_tk {
            // fully masked for every row in the chunk: skipped before any
            // raw k/v row is read or projected
            prof.blocks_skipped += chunk as u64;
            continue;
        }
        // ---- project the block once for the whole chunk -----------------
        for (jj, j) in (b.start..b.end).enumerate() {
            kv.project_pair_into(j, se2f, krow, vrow);
            kblock[jj * c..(jj + 1) * c].copy_from_slice(krow);
            vblock[jj * c..(jj + 1) * c].copy_from_slice(vrow);
        }
        // pull the next block's raw rows toward L1 while this block's
        // tile is attended (no-op off x86_64)
        if let Some(nb) = blocks.get(bi + 1) {
            prefetch_read(kv.k, nb.start * kv.d);
            prefetch_read(kv.v, nb.start * kv.d);
        }
        // ---- attend every chunk row against the tile --------------------
        for r in 0..chunk {
            let tqi = tq[r];
            if tqi < b.min_tk {
                prof.blocks_skipped += 1;
                continue;
            }
            prof.blocks_visited += 1;
            let qi = &qt[r * c..(r + 1) * c];
            let fully_visible = tqi >= b.max_tk;
            let m_i = mstate[r];
            let accr = &mut acc[r * c..(r + 1) * c];
            let mut bmax = f64::NEG_INFINITY;
            for (jj, j) in (b.start..b.end).enumerate() {
                s[jj] = if fully_visible || tqi >= tk[j] {
                    prof.k_rows_read += 1;
                    let kj = &kblock[jj * c..(jj + 1) * c];
                    let sv = dot_lanes::<L>(qi, kj) * scale;
                    if sv > bmax {
                        bmax = sv;
                    }
                    sv
                } else {
                    f64::NEG_INFINITY
                };
            }
            let m_new = if bmax > m_i { bmax } else { m_i };
            let alpha = (m_i - m_new).exp(); // m_i == -inf  =>  alpha == 0
            vacc.iter_mut().for_each(|x| *x = 0.0);
            let mut l_b = 0.0f64;
            for jj in 0..(b.end - b.start) {
                let sv = s[jj];
                if sv == f64::NEG_INFINITY {
                    continue;
                }
                let p = (sv - m_new).exp();
                l_b += p;
                prof.v_rows_read += 1;
                let vj = &vblock[jj * c..(jj + 1) * c];
                axpy_lanes::<L>(vacc, p as f32, vj);
            }
            lstate[r] = lstate[r] * alpha + l_b;
            for (a, &vb) in accr.iter_mut().zip(vacc.iter()) {
                *a = *a * alpha + vb as f64;
            }
            mstate[r] = m_new;
        }
    }
    for r in 0..chunk {
        let out_row = &mut ot[r * c..(r + 1) * c];
        if lstate[r] > 0.0 {
            let accr = &acc[r * c..(r + 1) * c];
            for (o, &a) in out_row.iter_mut().zip(accr.iter()) {
                *o = (a / lstate[r]) as f32;
            }
        } else {
            // all-masked query row: defined as zero, never 0/0 = NaN
            out_row.iter_mut().for_each(|o| *o = 0.0);
        }
    }
}

/// Flush one chunk's profiling counters (shared by the fused drivers;
/// mirrors the per-chunk flush in [`flash_sdpa_rows`]).
fn flush_chunk_profile(rows: usize, prof: &RowProfile) {
    if crate::trace::profiling() {
        use std::sync::atomic::Ordering::Relaxed;
        let p = crate::trace::kernel_profile();
        p.chunks.fetch_add(1, Relaxed);
        p.rows.fetch_add(rows as u64, Relaxed);
        p.key_blocks_visited.fetch_add(prof.blocks_visited, Relaxed);
        p.key_blocks_skipped.fetch_add(prof.blocks_skipped, Relaxed);
    }
}

/// Flush one call's profiling summary (shared by the fused drivers).
fn flush_call_profile(threads: usize, scratch: usize) {
    if crate::trace::profiling() {
        use std::sync::atomic::Ordering::Relaxed;
        let p = crate::trace::kernel_profile();
        p.calls.fetch_add(1, Relaxed);
        p.participants.fetch_add(threads as u64, Relaxed);
        p.scratch_bytes.fetch_add(scratch as u64, Relaxed);
    }
}

/// Fused key-side driver behind the [`KvRowSource::RawPose`] dispatch in
/// [`flash_sdpa_rows`]: projected queries in, attended o~ out, k/v
/// projected per block into per-thread scratch.
#[allow(clippy::too_many_arguments)]
fn fused_rows(
    qt: &[f32],
    kv: &RawPoseKv<'_>,
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    let n = tq.len();
    let m = tk.len();
    if n == 0 {
        return 0;
    }
    let blocks = key_blocks(tk, cfg.block_m);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let block_m = cfg.block_m.min(m.max(1));
    let attend_t0 = crate::trace::enabled().then(std::time::Instant::now);

    let threads = run_chunked(n, ROWS_PER_TASK, cfg.threads, &|lo, hi| {
        FUSED_SCRATCH.with(|cell| {
            let _mem = crate::obs::alloc::MemScope::enter("kernel_scratch");
            let mut sc = cell.borrow_mut();
            sc.ensure(hi - lo, block_m, c, kv);
            let mut prof = RowProfile::default();
            // chunks own disjoint contiguous row ranges of the output
            let ot = unsafe { out_ptr.slice_mut(lo * c, (hi - lo) * c) };
            let qt_chunk = &qt[lo * c..hi * c];
            match cfg.lanes {
                4 => attend_chunk_fused::<4>(
                    qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot, &mut prof,
                ),
                16 => attend_chunk_fused::<16>(
                    qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot, &mut prof,
                ),
                _ => attend_chunk_fused::<8>(
                    qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot, &mut prof,
                ),
            }
            flush_chunk_profile(hi - lo, &prof);
        });
    });
    let scratch = threads * cfg.scratch_bytes_per_thread_fused(c, m);
    flush_call_profile(threads, scratch);
    if let Some(t0) = attend_t0 {
        crate::trace::record_since(crate::trace::Stage::Attend, t0, n as u64);
    }
    scratch
}

/// Fully fused Algorithm 2 kernel (DESIGN.md §18): raw (n x d) queries +
/// query poses in, raw (n x d) outputs out.  Per chunk of query rows the
/// driver projects q~ into per-thread scratch (line 1), attends through
/// the fused key-block loop — each visited block's k~/v~ rows projected
/// on the fly from `kv` (line 2), never materialized — and unprojects o~
/// back to width d (line 4).  The only transients are the per-thread
/// scratch tiles ([`KernelConfig::scratch_bytes_per_thread_fused`]);
/// returns their total bytes across participating threads.
///
/// Output is bit-identical to `linear::attention_projected_with` for the
/// same config: every projected value and every reduction step matches.
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_fused(
    q: &[f32],
    pose_q: &[Pose],
    kv: &RawPoseKv<'_>,
    tq: &[i32],
    tk: &[i32],
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    let n = tq.len();
    let m = tk.len();
    let d = kv.d;
    let c = kv.proj_width();
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(pose_q.len(), n, "pose_q shape");
    assert_eq!(out.len(), n * d, "out shape");
    KvRowSource::RawPose { kv, value_side: false }.assert_shape(c, m, "k");
    KvRowSource::RawPose { kv, value_side: true }.assert_shape(c, m, "v");
    let cfg = cfg.normalized();
    if n == 0 {
        return 0;
    }
    let blocks = key_blocks(tk, cfg.block_m);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let block_m = cfg.block_m.min(m.max(1));
    let attend_t0 = crate::trace::enabled().then(std::time::Instant::now);

    let threads = run_chunked(n, ROWS_PER_TASK, cfg.threads, &|lo, hi| {
        FUSED_SCRATCH.with(|cell| {
            let _mem = crate::obs::alloc::MemScope::enter("kernel_scratch");
            let mut sc = cell.borrow_mut();
            sc.ensure(hi - lo, block_m, c, kv);
            let chunk = hi - lo;
            // take the q~/o~ tiles out of the scratch so the chunk body
            // can borrow the rest of it mutably alongside them
            let mut qtile = std::mem::take(&mut sc.qt);
            let mut otile = std::mem::take(&mut sc.ot);
            for (r, i) in (lo..hi).enumerate() {
                proj::project_q_row_into(
                    kv.method,
                    &q[i * d..(i + 1) * d],
                    &pose_q[i],
                    kv.scales,
                    kv.fourier_f,
                    kv.pref,
                    &mut sc.krow,
                );
                qtile[r * c..(r + 1) * c].copy_from_slice(&sc.krow);
            }
            let mut prof = RowProfile::default();
            {
                let qt_chunk = &qtile[..chunk * c];
                let ot_chunk = &mut otile[..chunk * c];
                match cfg.lanes {
                    4 => attend_chunk_fused::<4>(
                        qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot_chunk,
                        &mut prof,
                    ),
                    16 => attend_chunk_fused::<16>(
                        qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot_chunk,
                        &mut prof,
                    ),
                    _ => attend_chunk_fused::<8>(
                        qt_chunk, &tq[lo..hi], kv, tk, c, scale, &blocks, &mut sc, ot_chunk,
                        &mut prof,
                    ),
                }
            }
            for (r, i) in (lo..hi).enumerate() {
                proj::unproject_o_row_into(
                    kv.method,
                    &otile[r * c..(r + 1) * c],
                    &pose_q[i],
                    kv.scales,
                    kv.fourier_f,
                    &mut sc.krow,
                );
                let out_row = unsafe { out_ptr.slice_mut(i * d, d) };
                out_row.copy_from_slice(&sc.krow);
            }
            sc.qt = qtile;
            sc.ot = otile;
            flush_chunk_profile(hi - lo, &prof);
        });
    });
    let scratch = threads * cfg.scratch_bytes_per_thread_fused(c, m);
    flush_call_profile(threads, scratch);
    if let Some(t0) = attend_t0 {
        crate::trace::record_since(crate::trace::Stage::Attend, t0, n as u64);
    }
    scratch
}

/// Blocked, multithreaded flash SDPA over [`KvRowSource`] k/v rows (see
/// module docs).  Same masking/softmax contract as [`flash_sdpa_scalar`];
/// returns the total transient scratch bytes of the participating worker
/// threads (for `peak_temp_bytes` accounting — the resident per-thread
/// cost stays O(c), preserving the linear-memory claim per worker, with
/// quantized sources adding only the two c-wide dequantization rows).
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_rows(
    q: &[f32],
    k: KvRowSource<'_>,
    v: KvRowSource<'_>,
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    let n = tq.len();
    let m = tk.len();
    assert_eq!(q.len(), n * c, "q shape");
    k.assert_shape(c, m, "k");
    v.assert_shape(c, m, "v");
    assert_eq!(out.len(), n * c, "out shape");
    let cfg = cfg.normalized();
    if n == 0 {
        return 0;
    }
    // raw-pose sources take the fused block driver: projecting row-by-row
    // through the generic `row()` would rebuild quadrature scratch per
    // read, while the fused driver projects each key block once per chunk
    if let Some((kvk, k_side)) = k.raw_pose() {
        let (kvv, v_side) = v
            .raw_pose()
            .expect("a raw-pose k source requires a raw-pose v source");
        assert!(
            std::ptr::eq(kvk, kvv),
            "raw-pose k and v must view the same RawPoseKv"
        );
        assert!(
            !k_side && v_side,
            "k must be the key side and v the value side of the pair"
        );
        return fused_rows(q, kvk, tq, tk, c, scale, out, &cfg);
    }
    assert!(
        v.raw_pose().is_none(),
        "a raw-pose v source requires a raw-pose k source"
    );
    let quantized = k.is_quantized() || v.is_quantized();
    let blocks = key_blocks(tk, cfg.block_m);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let block_m = cfg.block_m.min(m.max(1));
    // the span clock is only read when tracing is live (one branch off)
    let attend_t0 = crate::trace::enabled().then(std::time::Instant::now);

    let threads = run_chunked(n, ROWS_PER_TASK, cfg.threads, &|lo, hi| {
        SCRATCH.with(|cell| {
            // per-thread scratch growth (`ensure` plus quantized-row
            // dequantization buffers) is charged to the kernel_scratch
            // scope — one scope enter per chunk, not per row
            let _mem = crate::obs::alloc::MemScope::enter("kernel_scratch");
            let mut sc = cell.borrow_mut();
            sc.ensure(block_m, c);
            let mut prof = RowProfile::default();
            for i in lo..hi {
                // disjoint per-row output slice — the only mutable state
                let out_row = unsafe { out_ptr.slice_mut(i * c, c) };
                let qi = &q[i * c..(i + 1) * c];
                match cfg.lanes {
                    4 => attend_row::<4>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                    16 => attend_row::<16>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                    _ => attend_row::<8>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                }
            }
            // one branch per chunk on the disabled path
            if crate::trace::profiling() {
                use std::sync::atomic::Ordering::Relaxed;
                let p = crate::trace::kernel_profile();
                p.chunks.fetch_add(1, Relaxed);
                p.rows.fetch_add((hi - lo) as u64, Relaxed);
                p.key_blocks_visited.fetch_add(prof.blocks_visited, Relaxed);
                p.key_blocks_skipped.fetch_add(prof.blocks_skipped, Relaxed);
                let dequant = prof.k_rows_read * k.is_quantized() as u64
                    + prof.v_rows_read * v.is_quantized() as u64;
                p.rows_dequantized.fetch_add(dequant, Relaxed);
            }
        });
    });
    let scratch = threads * cfg.scratch_bytes_per_thread_rows(c, m, quantized);
    if crate::trace::profiling() {
        use std::sync::atomic::Ordering::Relaxed;
        let p = crate::trace::kernel_profile();
        p.calls.fetch_add(1, Relaxed);
        p.participants.fetch_add(threads as u64, Relaxed);
        p.scratch_bytes.fetch_add(scratch as u64, Relaxed);
    }
    if let Some(t0) = attend_t0 {
        crate::trace::record_since(crate::trace::Stage::Attend, t0, n as u64);
    }
    scratch
}

/// Blocked, multithreaded flash SDPA over plain f32 slices — the
/// historical entry point, now a zero-copy wrapper over
/// [`flash_sdpa_rows`] (bit-identical to it on the same inputs).
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_blocked(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    flash_sdpa_rows(
        q,
        KvRowSource::F32(k),
        KvRowSource::F32(v),
        tq,
        tk,
        c,
        scale,
        out,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_inputs(
        rng: &mut Rng,
        n: usize,
        m: usize,
        c: usize,
        tmax: i64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>, Vec<i32>) {
        let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(rng, n * c);
        let k = gen(rng, m * c);
        let v = gen(rng, m * c);
        let tq: Vec<i32> = (0..n).map(|_| rng.int_range(0, tmax) as i32).collect();
        let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, tmax) as i32).collect();
        (q, k, v, tq, tk)
    }

    fn run_blocked(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        tq: &[i32],
        tk: &[i32],
        c: usize,
        cfg: &KernelConfig,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; tq.len() * c];
        let scale = 1.0 / (c as f64).sqrt();
        flash_sdpa_blocked(q, k, v, tq, tk, c, scale, &mut out, cfg);
        out
    }

    #[test]
    fn blocked_matches_scalar_on_random_inputs() {
        let mut rng = Rng::new(1234);
        for (n, m, c) in [(1usize, 1usize, 8usize), (7, 13, 24), (33, 65, 40)] {
            let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
            let scale = 1.0 / (c as f64).sqrt();
            let mut want = vec![0.0f32; n * c];
            flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut want);
            for block_m in [1usize, 3, 64, 1024] {
                let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(block_m, 8, 2));
                for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "n={n} m={m} c={c} block_m={block_m} [{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(99);
        let (n, m, c) = (37, 53, 20);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let base = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(16, 8, 1));
        for threads in [2usize, 4, 8] {
            let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(16, 8, threads));
            assert_eq!(base, got, "threads={threads} must be bit-identical");
        }
    }

    #[test]
    fn all_masked_rows_are_zero_not_nan() {
        let mut rng = Rng::new(7);
        let (n, m, c) = (5, 9, 12);
        let (q, k, v, _, _) = rand_inputs(&mut rng, n, m, c, 1);
        let tq = vec![-10i32; n]; // precede every key
        let tk: Vec<i32> = (0..m as i32).collect();
        let scale = 1.0 / (c as f64).sqrt();
        let mut scalar = vec![f32::NAN; n * c];
        flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut scalar);
        assert!(scalar.iter().all(|&x| x == 0.0), "scalar: zero, not NaN");
        let mut blocked = vec![f32::NAN; n * c];
        let cfg = KernelConfig::fixed(4, 8, 2);
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut blocked, &cfg);
        assert!(blocked.iter().all(|&x| x == 0.0), "blocked: zero, not NaN");
    }

    #[test]
    fn empty_key_set_yields_zero_rows() {
        let c = 6;
        let q = vec![1.0f32; 3 * c];
        let tq = vec![0i32; 3];
        let mut out = vec![f32::NAN; 3 * c];
        flash_sdpa_blocked(&q, &[], &[], &tq, &[], c, 1.0, &mut out, &KernelConfig::default());
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_skip_table_is_correct() {
        let tk = vec![5, 1, 3, 9, 9, 9, 0, 2];
        let blocks = key_blocks(&tk, 3);
        assert_eq!(blocks.len(), 3);
        assert_eq!((blocks[0].min_tk, blocks[0].max_tk), (1, 5));
        assert_eq!((blocks[1].min_tk, blocks[1].max_tk), (9, 9));
        assert_eq!((blocks[2].min_tk, blocks[2].max_tk), (0, 2));
        assert_eq!((blocks[2].start, blocks[2].end), (6, 8));
    }

    #[test]
    fn lane_variants_agree_with_scalar() {
        let mut rng = Rng::new(31);
        let (n, m, c) = (9, 17, 26); // ragged: c % every lane width != 0
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 2);
        let scale = 1.0 / (c as f64).sqrt();
        let mut want = vec![0.0f32; n * c];
        flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut want);
        for lanes in [4usize, 8, 16] {
            let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(8, lanes, 2));
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 1e-5, "lanes={lanes}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn config_normalization() {
        let cfg = KernelConfig {
            block_m: 0,
            lanes: 7,
            threads: 10_000,
        }
        .normalized();
        assert_eq!(cfg.block_m, 1);
        assert_eq!(cfg.lanes, KernelConfig::DEFAULT_LANES);
        assert_eq!(cfg.threads, 32);
        let d = KernelConfig::default();
        assert!(d.threads >= 1);
        assert!(d.block_m >= 1);
        assert_eq!(KernelConfig::with_threads(0).block_m, d.block_m);
        assert_eq!(KernelConfig::with_threads(3).threads, 3);
    }

    #[test]
    fn scratch_accounting_is_o_c_per_thread() {
        let cfg = KernelConfig::fixed(64, 8, 4);
        let per = cfg.scratch_bytes_per_thread(100, 1000);
        assert_eq!(per, 64 * 8 + 100 * 4 + 100 * 8);
        // block capped by m
        assert_eq!(
            cfg.scratch_bytes_per_thread(100, 16),
            16 * 8 + 100 * 4 + 100 * 8
        );
        // quantized sources add exactly the two c-wide dequant rows
        assert_eq!(
            cfg.scratch_bytes_per_thread_rows(100, 16, true),
            cfg.scratch_bytes_per_thread(100, 16) + 2 * 100 * 4
        );
        assert_eq!(
            cfg.scratch_bytes_per_thread_rows(100, 16, false),
            cfg.scratch_bytes_per_thread(100, 16)
        );
    }

    #[test]
    fn profiling_counters_accumulate_when_enabled() {
        use crate::trace::{KernelProfile, ProfileGuard};
        let mut rng = Rng::new(77);
        let (n, m, c) = (16usize, 32usize, 8usize);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
        let before = KernelProfile::snapshot();
        let _g = ProfileGuard::enable();
        run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(8, 8, 2));
        let d = KernelProfile::snapshot().delta(&before);
        assert!(d.calls >= 1, "calls: {}", d.calls);
        assert!(d.rows >= n as u64, "rows: {}", d.rows);
        assert!(d.chunks >= 1);
        assert!(d.participants >= 1);
        assert!(d.key_blocks_visited + d.key_blocks_skipped >= 1);
        assert!(d.scratch_bytes > 0);
        // f32 sources never dequantize (no quantized-row reads recorded
        // by THIS call; concurrent tests can only add, not subtract)
    }

    #[test]
    fn quantized_profiling_counts_dequantized_rows() {
        use crate::attention::quant::FeatureRows;
        use crate::config::CachePrecision;
        use crate::trace::{KernelProfile, ProfileGuard};
        let mut rng = Rng::new(78);
        let (n, m, c) = (8usize, 16usize, 8usize);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
        let mut kq = FeatureRows::new(CachePrecision::F16, c);
        kq.push_rows(&k);
        let mut vq = FeatureRows::new(CachePrecision::F16, c);
        vq.push_rows(&v);
        let before = KernelProfile::snapshot();
        let _g = ProfileGuard::enable();
        let mut out = vec![0.0f32; n * c];
        let scale = 1.0 / (c as f64).sqrt();
        flash_sdpa_rows(
            &q,
            kq.as_kv(),
            vq.as_kv(),
            &tq,
            &tk,
            c,
            scale,
            &mut out,
            &KernelConfig::fixed(8, 8, 1),
        );
        let d = KernelProfile::snapshot().delta(&before);
        assert!(d.rows_dequantized >= 1, "dequant rows: {}", d.rows_dequantized);
    }

    #[test]
    fn f32_row_source_is_bit_identical_to_slice_entry_point() {
        use crate::attention::quant::KvRowSource;
        let mut rng = Rng::new(21);
        let (n, m, c) = (9, 23, 18);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let scale = 1.0 / (c as f64).sqrt();
        let cfg = KernelConfig::fixed(7, 8, 2);
        let mut a = vec![0.0f32; n * c];
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut a, &cfg);
        let mut b = vec![0.0f32; n * c];
        flash_sdpa_rows(
            &q,
            KvRowSource::F32(&k),
            KvRowSource::F32(&v),
            &tq,
            &tk,
            c,
            scale,
            &mut b,
            &cfg,
        );
        assert_eq!(a, b, "wrapper and row-source path must agree bitwise");
    }

    #[test]
    fn quantized_row_source_tracks_the_f32_kernel() {
        use crate::attention::quant::FeatureRows;
        use crate::config::CachePrecision;
        let mut rng = Rng::new(22);
        let (n, m, c) = (11, 37, 26);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let scale = 1.0 / (c as f64).sqrt();
        let cfg = KernelConfig::fixed(8, 8, 2);
        let mut want = vec![0.0f32; n * c];
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut want, &cfg);
        for (codec, tol) in [(CachePrecision::F16, 2e-2f32), (CachePrecision::Bf16, 1e-1)] {
            let mut kq = FeatureRows::new(codec, c);
            kq.push_rows(&k);
            let mut vq = FeatureRows::new(codec, c);
            vq.push_rows(&v);
            let mut got = vec![f32::NAN; n * c];
            flash_sdpa_rows(
                &q,
                kq.as_kv(),
                vq.as_kv(),
                &tq,
                &tk,
                c,
                scale,
                &mut got,
                &cfg,
            );
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < tol, "{codec:?} [{i}]: {a} vs {b}");
            }
        }
        // quantized all-masked rows are still exact zeros, never NaN
        let tq_masked = vec![-10i32; n];
        let kq = {
            let mut s = FeatureRows::new(CachePrecision::F16, c);
            s.push_rows(&k);
            s
        };
        let vq = {
            let mut s = FeatureRows::new(CachePrecision::F16, c);
            s.push_rows(&v);
            s
        };
        let mut out = vec![f32::NAN; n * c];
        flash_sdpa_rows(
            &q,
            kq.as_kv(),
            vq.as_kv(),
            &tq_masked,
            &tk,
            c,
            scale,
            &mut out,
            &cfg,
        );
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn malformed_kernel_env_falls_back_to_defaults() {
        // malformed values behave exactly like unset ones (no panic, same
        // defaults), so this test cannot perturb the process-wide Default
        // OnceLock even when other tests resolve it concurrently
        for bad in ["abc", "", " ", "0", "-3", "1.5", "8x"] {
            std::env::set_var("SE2ATTN_KERNEL_BLOCK_M", bad);
            std::env::set_var("SE2ATTN_KERNEL_LANES", bad);
            std::env::set_var("SE2ATTN_KERNEL_THREADS", bad);
            let cfg = KernelConfig::from_env();
            assert_eq!(cfg.block_m, KernelConfig::DEFAULT_BLOCK_M, "{bad:?}");
            assert_eq!(cfg.lanes, KernelConfig::DEFAULT_LANES, "{bad:?}");
            assert_eq!(cfg.threads, default_workers().clamp(1, 32), "{bad:?}");
        }
        std::env::remove_var("SE2ATTN_KERNEL_BLOCK_M");
        std::env::remove_var("SE2ATTN_KERNEL_LANES");
        std::env::remove_var("SE2ATTN_KERNEL_THREADS");
    }

    #[test]
    fn autotune_is_cached_and_normalized() {
        let a = KernelConfig::autotune();
        let b = KernelConfig::autotune();
        assert_eq!(a, b, "autotune must return one config per process");
        assert_eq!(a, a.normalized(), "autotuned config must be normalized");
        assert!(matches!(a.lanes, 4 | 8 | 16));
        assert!(a.block_m >= 1);
        assert!((1..=32).contains(&a.threads));
    }

    #[test]
    fn autotuned_config_is_bit_identical_to_explicit() {
        // the tuner only picks WHICH shape runs; for a fixed shape the
        // kernel is a pure function of its inputs, so an autotuned run
        // must match an explicit-config run bit for bit
        let mut rng = Rng::new(4040);
        let (n, m, c) = (19, 41, 24);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
        let tuned = KernelConfig::autotune();
        let explicit = KernelConfig::fixed(tuned.block_m, tuned.lanes, tuned.threads);
        let a = run_blocked(&q, &k, &v, &tq, &tk, c, &tuned);
        let b = run_blocked(&q, &k, &v, &tq, &tk, c, &explicit);
        assert_eq!(a, b);
        // and thread count still never changes bits
        let t1 = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(tuned.block_m, tuned.lanes, 1));
        assert_eq!(a, t1);
    }

    fn raw_kv_case(
        rng: &mut Rng,
        d: usize,
        n: usize,
        m: usize,
    ) -> (
        Vec<f32>,
        Vec<f32>,
        Vec<f32>,
        Vec<crate::geometry::Pose>,
        Vec<crate::geometry::Pose>,
        Vec<i32>,
        Vec<i32>,
    ) {
        use crate::geometry::Pose;
        let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(rng, n * d);
        let k = gen(rng, m * d);
        let v = gen(rng, m * d);
        let pose = |rng: &mut Rng| {
            Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1))
        };
        let pq: Vec<Pose> = (0..n).map(|_| pose(rng)).collect();
        let pk: Vec<Pose> = (0..m).map(|_| pose(rng)).collect();
        let mut tq: Vec<i32> = (0..n).map(|_| rng.int_range(0, 4) as i32).collect();
        let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, 4) as i32).collect();
        tq[0] = -100; // an all-masked query row rides along
        (q, k, v, pq, pk, tq, tk)
    }

    const RAW_METHODS: [(Method, usize, usize); 4] = [
        (Method::Abs, 8, 0),
        (Method::Rope2d, 8, 0),
        (Method::Se2Rep, 9, 0),
        (Method::Se2Fourier, 12, 4),
    ];

    #[test]
    fn raw_pose_row_source_is_bit_identical_to_preprojected() {
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(4242);
        for (method, d, f) in RAW_METHODS {
            let (n, m) = (13usize, 29usize);
            let (_q, k, v, _pq, pk, tq, tk) = raw_kv_case(&mut rng, d, n, m);
            let kv = RawPoseKv {
                k: &k,
                v: &v,
                poses: &pk,
                method,
                d,
                fourier_f: f,
                scales: &scales,
                pref: 1.1,
            };
            let c = kv.proj_width();
            // materialize k~/v~ through the exact same pair projection
            let mut kt = vec![0.0f32; m * c];
            let mut vt = vec![0.0f32; m * c];
            let mut se2f = None;
            let (mut kr, mut vr) = (Vec::new(), Vec::new());
            for j in 0..m {
                kv.project_pair_into(j, &mut se2f, &mut kr, &mut vr);
                kt[j * c..(j + 1) * c].copy_from_slice(&kr);
                vt[j * c..(j + 1) * c].copy_from_slice(&vr);
            }
            let qt: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
            let scale = 1.0 / (c as f64).sqrt();
            let cfg = KernelConfig::fixed(5, 8, 3);
            let mut want = vec![0.0f32; n * c];
            flash_sdpa_blocked(&qt, &kt, &vt, &tq, &tk, c, scale, &mut want, &cfg);
            let mut got = vec![f32::NAN; n * c];
            flash_sdpa_rows(
                &qt,
                KvRowSource::RawPose { kv: &kv, value_side: false },
                KvRowSource::RawPose { kv: &kv, value_side: true },
                &tq,
                &tk,
                c,
                scale,
                &mut got,
                &cfg,
            );
            assert_eq!(want, got, "{method:?}: fused block driver must be bitwise");
        }
    }

    #[test]
    fn fused_entry_point_matches_project_then_attend_bitwise() {
        let scales = [1.0, 0.5];
        let mut rng = Rng::new(777);
        for (method, d, f) in RAW_METHODS {
            let (n, m) = (11usize, 23usize);
            let (q, k, v, pq, pk, tq, tk) = raw_kv_case(&mut rng, d, n, m);
            let cdim = match method {
                Method::Se2Fourier => (4 * f + 2) * (d / 6),
                _ => d,
            };
            let pref = ((cdim as f64) / (d as f64)).powf(0.25) as f32;
            let kv = RawPoseKv {
                k: &k,
                v: &v,
                poses: &pk,
                method,
                d,
                fourier_f: f,
                scales: &scales,
                pref,
            };
            let c = kv.proj_width();
            assert_eq!(c, cdim);
            // explicit project -> blocked attend -> unproject
            let mut qt = vec![0.0f32; n * c];
            let mut row = Vec::new();
            for i in 0..n {
                proj::project_q_row_into(
                    method, &q[i * d..(i + 1) * d], &pq[i], &scales, f, pref, &mut row,
                );
                qt[i * c..(i + 1) * c].copy_from_slice(&row);
            }
            let mut kt = vec![0.0f32; m * c];
            let mut vt = vec![0.0f32; m * c];
            let mut se2f = None;
            let (mut kr, mut vr) = (Vec::new(), Vec::new());
            for j in 0..m {
                kv.project_pair_into(j, &mut se2f, &mut kr, &mut vr);
                kt[j * c..(j + 1) * c].copy_from_slice(&kr);
                vt[j * c..(j + 1) * c].copy_from_slice(&vr);
            }
            let scale = 1.0 / (c as f64).sqrt();
            let cfg = KernelConfig::fixed(7, 8, 2);
            let mut ot = vec![0.0f32; n * c];
            flash_sdpa_blocked(&qt, &kt, &vt, &tq, &tk, c, scale, &mut ot, &cfg);
            let mut want = vec![0.0f32; n * d];
            for i in 0..n {
                proj::unproject_o_row_into(
                    method, &ot[i * c..(i + 1) * c], &pq[i], &scales, f, &mut row,
                );
                want[i * d..(i + 1) * d].copy_from_slice(&row);
            }
            // fused: one call, no projected intermediates
            let mut got = vec![f32::NAN; n * d];
            flash_sdpa_fused(&q, &pq, &kv, &tq, &tk, scale, &mut got, &cfg);
            assert_eq!(want, got, "{method:?}: fully fused path must be bitwise");
            // bit-stable across thread counts
            for threads in [1usize, 4, 8] {
                let mut t = vec![f32::NAN; n * d];
                flash_sdpa_fused(
                    &q,
                    &pq,
                    &kv,
                    &tq,
                    &tk,
                    scale,
                    &mut t,
                    &KernelConfig::fixed(7, 8, threads),
                );
                assert_eq!(got, t, "{method:?} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_scratch_accounting_is_o_block_c_per_thread() {
        let cfg = KernelConfig::fixed(64, 8, 4);
        let chunk = super::ROWS_PER_TASK;
        assert_eq!(
            cfg.scratch_bytes_per_thread_fused(100, 1000),
            (64 + 2 * chunk + chunk * 100) * 8
                + (2 * chunk * 100 + 2 * 64 * 100 + 4 * 100) * 4
        );
        // block capped by m
        assert_eq!(
            cfg.scratch_bytes_per_thread_fused(100, 16),
            (16 + 2 * chunk + chunk * 100) * 8
                + (2 * chunk * 100 + 2 * 16 * 100 + 4 * 100) * 4
        );
        // constant in m beyond the cap — the linear-memory claim per thread
        assert_eq!(
            cfg.scratch_bytes_per_thread_fused(100, 1_000),
            cfg.scratch_bytes_per_thread_fused(100, 1_000_000)
        );
    }
}
