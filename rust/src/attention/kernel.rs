//! Blocked, cache-tiled, multithreaded flash-SDPA kernel — the CPU mirror
//! of the Pallas kernel's grid/block structure (DESIGN.md §13).
//!
//! Every native attention path in the repo (Algorithm 2 in
//! [`super::linear`], the quadratic oracle's row partition in
//! [`super::quadratic`], and the incremental decode engine's cached-row
//! attend in [`super::incremental`]) funnels through this module, behind a
//! [`KernelConfig`].  Two implementations share one contract:
//!
//! * [`flash_sdpa_scalar`] — the original scalar, single-threaded,
//!   per-element loop.  Kept verbatim as the **oracle reference**: the
//!   equivalence suite and the CI perf gate compare the blocked kernel
//!   against it.
//! * [`flash_sdpa_blocked`] — key/value rows processed in fixed-size
//!   blocks of `block_m` rows (the Pallas `kv` grid axis), with
//!   vectorizer-friendly fixed-lane inner loops over the feature width
//!   `c` (f32 block math feeding the existing f64 online-softmax running
//!   state), query rows partitioned across the reusable scoped thread
//!   pool ([`crate::exec::shared_pool`]), and a precomputed per-block
//!   causal-visibility table so fully masked key blocks are never read.
//!
//! ## Row sources
//!
//! The blocked kernel reads k/v rows through
//! [`KvRowSource`] (see [`super::quant`]), so the *same*
//! tiled loop serves raw f32 matrices (zero-copy row borrows — the f32
//! path is bit-identical to a kernel hard-coded on slices) and the
//! quantized f16/bf16 feature caches (each visible row is dequantized on
//! the fly into O(c) per-thread scratch inside the key-block loop).
//! [`flash_sdpa_rows`] is the row-source entry point;
//! [`flash_sdpa_blocked`] wraps it for plain slices.
//!
//! ## Determinism
//!
//! For a fixed `(block_m, lanes)` the blocked kernel is **bit-stable
//! across thread counts**: threads partition *query rows*, and each row's
//! reduction order (key blocks in order, lanes chunked in fixed sizes,
//! rows within a block in order) is a pure function of the inputs — no
//! cross-thread reduction exists.  `threads` only changes wall-clock,
//! never output bits.  Changing `block_m` or `lanes` changes the rounding
//! order and may perturb outputs within the f32 noise floor (the
//! equivalence suite bounds it at 1e-5 against the scalar oracle).
//!
//! ## All-masked query rows (pinned behavior)
//!
//! A query row whose timestamp precedes every key (`tq[i] < tk[j]` for all
//! j) has an empty softmax: `l_i == 0`.  Both kernels define its output as
//! an exact **zero row** — never `0/0 = NaN`.  `tests/kernel_equivalence.rs`
//! pins this for both paths.

use std::cell::RefCell;

use crate::config::default_workers;
use crate::exec::{run_chunked, SendPtr};

use super::quant::KvRowSource;

/// Query rows claimed per pool task: small enough to load-balance ragged
/// visibility masks, large enough to amortize the work-stealing counter.
const ROWS_PER_TASK: usize = 8;

/// Configuration of the blocked flash kernel.  `Default` resolves the
/// `SE2ATTN_KERNEL_{BLOCK_M,LANES,THREADS}` environment overrides once
/// per process and otherwise uses `block_m = 64`, `lanes = 8`,
/// `threads =` [`default_workers`] — so every call site that does not
/// plumb an explicit config still agrees on one kernel shape (bit-stable
/// results between e.g. `linear::attention` and the incremental engine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Key/value rows per block (the Pallas `kv` block dimension).
    pub block_m: usize,
    /// f32 lanes in the fixed-lane inner loops (4, 8 or 16 — anything
    /// else is normalized to 8).
    pub lanes: usize,
    /// Worker threads the query-row partition may use (the calling
    /// thread counts as one; `threads - 1` come from the shared pool).
    pub threads: usize,
}

impl KernelConfig {
    pub const DEFAULT_BLOCK_M: usize = 64;
    pub const DEFAULT_LANES: usize = 8;

    /// Fully explicit config (tests and benches — no env, no host probing).
    pub fn fixed(block_m: usize, lanes: usize, threads: usize) -> KernelConfig {
        KernelConfig {
            block_m,
            lanes,
            threads,
        }
        .normalized()
    }

    /// The default shape with an explicit thread count (`0` = keep the
    /// default) — the CLI / `ServeConfig` plumbing entry point.
    pub fn with_threads(threads: usize) -> KernelConfig {
        let mut cfg = KernelConfig::default();
        if threads > 0 {
            cfg.threads = threads;
        }
        cfg.normalized()
    }

    /// Read `SE2ATTN_KERNEL_{BLOCK_M,LANES,THREADS}` (each optional) on
    /// top of the built-in defaults.  Called once per process by
    /// `Default`; call directly to re-read the environment.
    pub fn from_env() -> KernelConfig {
        let var = |name: &str, fallback: usize| -> usize {
            std::env::var(name)
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&v| v > 0)
                .unwrap_or(fallback)
        };
        KernelConfig {
            block_m: var("SE2ATTN_KERNEL_BLOCK_M", Self::DEFAULT_BLOCK_M),
            lanes: var("SE2ATTN_KERNEL_LANES", Self::DEFAULT_LANES),
            threads: var("SE2ATTN_KERNEL_THREADS", default_workers()),
        }
        .normalized()
    }

    /// Clamp to shapes the kernel supports (lanes ∈ {4, 8, 16}; at least
    /// one key row per block; 1..=32 threads).
    pub fn normalized(&self) -> KernelConfig {
        KernelConfig {
            block_m: self.block_m.max(1),
            lanes: match self.lanes {
                4 | 8 | 16 => self.lanes,
                _ => Self::DEFAULT_LANES,
            },
            threads: self.threads.clamp(1, 32),
        }
    }

    /// Transient bytes of one worker thread's scratch (scores block +
    /// f32 value-block accumulator + f64 running accumulator) — the
    /// per-thread term of the linear-memory claim.  Quantized row
    /// sources add two c-wide f32 dequantization buffers per thread
    /// ([`Self::scratch_bytes_per_thread_rows`]); either way the
    /// per-thread cost stays O(c), independent of the context length m.
    pub fn scratch_bytes_per_thread(&self, c: usize, m: usize) -> usize {
        let bm = self.block_m.max(1).min(m.max(1));
        bm * std::mem::size_of::<f64>()
            + c * std::mem::size_of::<f32>()
            + c * std::mem::size_of::<f64>()
    }

    /// [`Self::scratch_bytes_per_thread`] plus the k/v dequantization
    /// buffers a quantized row source needs (2 c-wide f32 rows).
    pub fn scratch_bytes_per_thread_rows(&self, c: usize, m: usize, quantized: bool) -> usize {
        self.scratch_bytes_per_thread(c, m)
            + if quantized {
                2 * c * std::mem::size_of::<f32>()
            } else {
                0
            }
    }
}

impl Default for KernelConfig {
    fn default() -> KernelConfig {
        static AUTO: std::sync::OnceLock<KernelConfig> = std::sync::OnceLock::new();
        *AUTO.get_or_init(KernelConfig::from_env)
    }
}

// ---------------------------------------------------------------------------
// Scalar oracle
// ---------------------------------------------------------------------------

/// Streaming SDPA over projected tensors: q (n x c), k/v (m x c), online
/// softmax with visibility rule `tq >= tk`, O(c) transient state.  The
/// scalar, single-threaded oracle the blocked kernel is verified against;
/// an all-masked query row is a defined zero row.
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_scalar(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
) {
    let n = tq.len();
    let m = tk.len();
    debug_assert_eq!(q.len(), n * c, "q shape");
    debug_assert_eq!(k.len(), m * c, "k shape");
    debug_assert_eq!(v.len(), m * c, "v shape");
    debug_assert_eq!(out.len(), n * c, "out shape");
    let mut acc = vec![0.0f64; c];
    for i in 0..n {
        let qi = &q[i * c..(i + 1) * c];
        let mut m_i = f64::NEG_INFINITY;
        let mut l_i = 0.0f64;
        acc.iter_mut().for_each(|a| *a = 0.0);
        for j in 0..m {
            if tq[i] < tk[j] {
                continue;
            }
            let kj = &k[j * c..(j + 1) * c];
            let s: f64 = qi
                .iter()
                .zip(kj.iter())
                .map(|(a, b)| *a as f64 * *b as f64)
                .sum::<f64>()
                * scale;
            let m_new = m_i.max(s);
            let alpha = if m_i == f64::NEG_INFINITY {
                0.0
            } else {
                (m_i - m_new).exp()
            };
            let p = (s - m_new).exp();
            l_i = l_i * alpha + p;
            let vj = &v[j * c..(j + 1) * c];
            for (a, &vv) in acc.iter_mut().zip(vj.iter()) {
                *a = *a * alpha + p * vv as f64;
            }
            m_i = m_new;
        }
        let oi = &mut out[i * c..(i + 1) * c];
        if l_i > 0.0 {
            for (o, &a) in oi.iter_mut().zip(acc.iter()) {
                *o = (a / l_i) as f32;
            }
        } else {
            // all-masked query row: defined as zero, never 0/0 = NaN
            oi.iter_mut().for_each(|o| *o = 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked multithreaded kernel
// ---------------------------------------------------------------------------

/// Precomputed visibility envelope of one key block: with the rule
/// `visible(i, j) = tq[i] >= tk[j]`, a query with `tq < min_tk` sees
/// nothing in the block (skip — k/v rows never touched) and one with
/// `tq >= max_tk` sees everything (no per-row mask test in the hot loop).
#[derive(Clone, Copy, Debug)]
struct KeyBlock {
    start: usize,
    end: usize,
    min_tk: i32,
    max_tk: i32,
}

fn key_blocks(tk: &[i32], block_m: usize) -> Vec<KeyBlock> {
    let m = tk.len();
    let bm = block_m.max(1);
    let mut blocks = Vec::with_capacity(m.div_ceil(bm));
    let mut start = 0;
    while start < m {
        let end = (start + bm).min(m);
        let mut min_tk = i32::MAX;
        let mut max_tk = i32::MIN;
        for &t in &tk[start..end] {
            min_tk = min_tk.min(t);
            max_tk = max_tk.max(t);
        }
        blocks.push(KeyBlock {
            start,
            end,
            min_tk,
            max_tk,
        });
        start = end;
    }
    blocks
}

/// Per-thread scratch, reused across calls through a thread-local so pool
/// workers allocate once and keep their buffers warm.
#[derive(Default)]
struct RowScratch {
    /// Scores of one key block (f64 — the online-softmax state dtype).
    s: Vec<f64>,
    /// f32 block accumulator for `sum_j p_j * v_j` (the "f32 block math").
    vacc: Vec<f32>,
    /// f64 running output accumulator (carried across blocks).
    acc: Vec<f64>,
    /// Dequantization buffer for one key row (quantized sources only;
    /// stays empty on the f32 path, which borrows rows zero-copy).
    krow: Vec<f32>,
    /// Dequantization buffer for one value row (quantized sources only).
    vrow: Vec<f32>,
}

impl RowScratch {
    fn ensure(&mut self, block_m: usize, c: usize) {
        if self.s.len() < block_m {
            self.s.resize(block_m, 0.0);
        }
        if self.vacc.len() != c {
            self.vacc.resize(c, 0.0);
        }
        if self.acc.len() != c {
            self.acc.resize(c, 0.0);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<RowScratch> = RefCell::new(RowScratch::default());
}

/// Per-chunk profiling accumulator: plain register counters, incremented
/// unconditionally (the increments are free next to the row math) and
/// flushed to the global [`crate::trace::kernel_profile`] atomics once per
/// chunk *only* when profiling is enabled — the disabled path pays one
/// branch per chunk, nothing per row.
#[derive(Clone, Copy, Default)]
struct RowProfile {
    blocks_visited: u64,
    blocks_skipped: u64,
    k_rows_read: u64,
    v_rows_read: u64,
}

/// Fixed-lane dot product: L parallel f32 partial sums (vectorizer
/// fodder), combined left-to-right in f64, plus a scalar tail.  The
/// reduction order depends only on `L` and the slice length — never on
/// the executing thread.
#[inline]
fn dot_lanes<const L: usize>(a: &[f32], b: &[f32]) -> f64 {
    let chunks = a.len() / L;
    let mut acc = [0.0f32; L];
    for ch in 0..chunks {
        let ab = &a[ch * L..ch * L + L];
        let bb = &b[ch * L..ch * L + L];
        for l in 0..L {
            acc[l] += ab[l] * bb[l];
        }
    }
    let mut s = 0.0f64;
    for &x in acc.iter() {
        s += x as f64;
    }
    for t in chunks * L..a.len() {
        s += (a[t] * b[t]) as f64;
    }
    s
}

/// Fixed-lane `acc += x * v` over f32 (the value-block accumulation).
#[inline]
fn axpy_lanes<const L: usize>(acc: &mut [f32], x: f32, v: &[f32]) {
    let chunks = acc.len() / L;
    for ch in 0..chunks {
        let ab = &mut acc[ch * L..ch * L + L];
        let vb = &v[ch * L..ch * L + L];
        for l in 0..L {
            ab[l] += x * vb[l];
        }
    }
    for t in chunks * L..acc.len() {
        acc[t] += x * v[t];
    }
}

/// One query row against every key block: flash online softmax with one
/// rescale per *block* instead of per element.  `k`/`v` rows come
/// through a [`KvRowSource`]: borrowed zero-copy for f32 storage,
/// dequantized into the per-thread `sc.krow`/`sc.vrow` scratch for
/// quantized storage — the tiled loop is otherwise identical.
#[allow(clippy::too_many_arguments)]
fn attend_row<const L: usize>(
    qi: &[f32],
    k: &KvRowSource<'_>,
    v: &KvRowSource<'_>,
    tqi: i32,
    tk: &[i32],
    c: usize,
    scale: f64,
    blocks: &[KeyBlock],
    sc: &mut RowScratch,
    out_row: &mut [f32],
    prof: &mut RowProfile,
) {
    // split the scratch into disjoint field borrows once, so a row
    // dequantized into `krow` can be read while `s` is being written
    let RowScratch {
        s,
        vacc,
        acc,
        krow,
        vrow,
    } = sc;
    let mut m_i = f64::NEG_INFINITY;
    let mut l_i = 0.0f64;
    acc.iter_mut().for_each(|a| *a = 0.0);
    for b in blocks {
        if tqi < b.min_tk {
            // fully masked block: skipped before any k/v row is read
            prof.blocks_skipped += 1;
            continue;
        }
        prof.blocks_visited += 1;
        let fully_visible = tqi >= b.max_tk;
        // ---- scores (f32 lane math -> f64 block max) --------------------
        let mut bmax = f64::NEG_INFINITY;
        for (jj, j) in (b.start..b.end).enumerate() {
            s[jj] = if fully_visible || tqi >= tk[j] {
                prof.k_rows_read += 1;
                let kj = k.row(j, c, krow);
                let sv = dot_lanes::<L>(qi, kj) * scale;
                if sv > bmax {
                    bmax = sv;
                }
                sv
            } else {
                f64::NEG_INFINITY
            };
        }
        // tqi >= b.min_tk guarantees at least one visible key, so bmax is
        // finite and `alpha` below can never be exp(-inf - -inf) = NaN
        let m_new = if bmax > m_i { bmax } else { m_i };
        let alpha = (m_i - m_new).exp(); // m_i == -inf  =>  alpha == 0
        // ---- probabilities + f32 value-block accumulation ---------------
        vacc.iter_mut().for_each(|x| *x = 0.0);
        let mut l_b = 0.0f64;
        for (jj, j) in (b.start..b.end).enumerate() {
            let sv = s[jj];
            if sv == f64::NEG_INFINITY {
                continue;
            }
            let p = (sv - m_new).exp();
            l_b += p;
            prof.v_rows_read += 1;
            let vj = v.row(j, c, vrow);
            axpy_lanes::<L>(vacc, p as f32, vj);
        }
        // ---- fold the block into the f64 running state ------------------
        l_i = l_i * alpha + l_b;
        for (a, &vb) in acc.iter_mut().zip(vacc.iter()) {
            *a = *a * alpha + vb as f64;
        }
        m_i = m_new;
    }
    if l_i > 0.0 {
        for (o, &a) in out_row.iter_mut().zip(acc.iter()) {
            *o = (a / l_i) as f32;
        }
    } else {
        // all-masked query row: defined as zero, never 0/0 = NaN
        out_row.iter_mut().for_each(|o| *o = 0.0);
    }
}

/// Blocked, multithreaded flash SDPA over [`KvRowSource`] k/v rows (see
/// module docs).  Same masking/softmax contract as [`flash_sdpa_scalar`];
/// returns the total transient scratch bytes of the participating worker
/// threads (for `peak_temp_bytes` accounting — the resident per-thread
/// cost stays O(c), preserving the linear-memory claim per worker, with
/// quantized sources adding only the two c-wide dequantization rows).
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_rows(
    q: &[f32],
    k: KvRowSource<'_>,
    v: KvRowSource<'_>,
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    let n = tq.len();
    let m = tk.len();
    assert_eq!(q.len(), n * c, "q shape");
    k.assert_shape(c, m, "k");
    v.assert_shape(c, m, "v");
    assert_eq!(out.len(), n * c, "out shape");
    let cfg = cfg.normalized();
    if n == 0 {
        return 0;
    }
    let quantized = k.is_quantized() || v.is_quantized();
    let blocks = key_blocks(tk, cfg.block_m);
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let block_m = cfg.block_m.min(m.max(1));
    // the span clock is only read when tracing is live (one branch off)
    let attend_t0 = crate::trace::enabled().then(std::time::Instant::now);

    let threads = run_chunked(n, ROWS_PER_TASK, cfg.threads, &|lo, hi| {
        SCRATCH.with(|cell| {
            // per-thread scratch growth (`ensure` plus quantized-row
            // dequantization buffers) is charged to the kernel_scratch
            // scope — one scope enter per chunk, not per row
            let _mem = crate::obs::alloc::MemScope::enter("kernel_scratch");
            let mut sc = cell.borrow_mut();
            sc.ensure(block_m, c);
            let mut prof = RowProfile::default();
            for i in lo..hi {
                // disjoint per-row output slice — the only mutable state
                let out_row = unsafe { out_ptr.slice_mut(i * c, c) };
                let qi = &q[i * c..(i + 1) * c];
                match cfg.lanes {
                    4 => attend_row::<4>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                    16 => attend_row::<16>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                    _ => attend_row::<8>(
                        qi, &k, &v, tq[i], tk, c, scale, &blocks, &mut sc, out_row, &mut prof,
                    ),
                }
            }
            // one branch per chunk on the disabled path
            if crate::trace::profiling() {
                use std::sync::atomic::Ordering::Relaxed;
                let p = crate::trace::kernel_profile();
                p.chunks.fetch_add(1, Relaxed);
                p.rows.fetch_add((hi - lo) as u64, Relaxed);
                p.key_blocks_visited.fetch_add(prof.blocks_visited, Relaxed);
                p.key_blocks_skipped.fetch_add(prof.blocks_skipped, Relaxed);
                let dequant = prof.k_rows_read * k.is_quantized() as u64
                    + prof.v_rows_read * v.is_quantized() as u64;
                p.rows_dequantized.fetch_add(dequant, Relaxed);
            }
        });
    });
    let scratch = threads * cfg.scratch_bytes_per_thread_rows(c, m, quantized);
    if crate::trace::profiling() {
        use std::sync::atomic::Ordering::Relaxed;
        let p = crate::trace::kernel_profile();
        p.calls.fetch_add(1, Relaxed);
        p.participants.fetch_add(threads as u64, Relaxed);
        p.scratch_bytes.fetch_add(scratch as u64, Relaxed);
    }
    if let Some(t0) = attend_t0 {
        crate::trace::record_since(crate::trace::Stage::Attend, t0, n as u64);
    }
    scratch
}

/// Blocked, multithreaded flash SDPA over plain f32 slices — the
/// historical entry point, now a zero-copy wrapper over
/// [`flash_sdpa_rows`] (bit-identical to it on the same inputs).
#[allow(clippy::too_many_arguments)]
pub fn flash_sdpa_blocked(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    tq: &[i32],
    tk: &[i32],
    c: usize,
    scale: f64,
    out: &mut [f32],
    cfg: &KernelConfig,
) -> usize {
    flash_sdpa_rows(
        q,
        KvRowSource::F32(k),
        KvRowSource::F32(v),
        tq,
        tk,
        c,
        scale,
        out,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_inputs(
        rng: &mut Rng,
        n: usize,
        m: usize,
        c: usize,
        tmax: i64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>, Vec<i32>) {
        let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        let q = gen(rng, n * c);
        let k = gen(rng, m * c);
        let v = gen(rng, m * c);
        let tq: Vec<i32> = (0..n).map(|_| rng.int_range(0, tmax) as i32).collect();
        let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, tmax) as i32).collect();
        (q, k, v, tq, tk)
    }

    fn run_blocked(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        tq: &[i32],
        tk: &[i32],
        c: usize,
        cfg: &KernelConfig,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; tq.len() * c];
        let scale = 1.0 / (c as f64).sqrt();
        flash_sdpa_blocked(q, k, v, tq, tk, c, scale, &mut out, cfg);
        out
    }

    #[test]
    fn blocked_matches_scalar_on_random_inputs() {
        let mut rng = Rng::new(1234);
        for (n, m, c) in [(1usize, 1usize, 8usize), (7, 13, 24), (33, 65, 40)] {
            let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
            let scale = 1.0 / (c as f64).sqrt();
            let mut want = vec![0.0f32; n * c];
            flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut want);
            for block_m in [1usize, 3, 64, 1024] {
                let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(block_m, 8, 2));
                for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "n={n} m={m} c={c} block_m={block_m} [{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(99);
        let (n, m, c) = (37, 53, 20);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let base = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(16, 8, 1));
        for threads in [2usize, 4, 8] {
            let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(16, 8, threads));
            assert_eq!(base, got, "threads={threads} must be bit-identical");
        }
    }

    #[test]
    fn all_masked_rows_are_zero_not_nan() {
        let mut rng = Rng::new(7);
        let (n, m, c) = (5, 9, 12);
        let (q, k, v, _, _) = rand_inputs(&mut rng, n, m, c, 1);
        let tq = vec![-10i32; n]; // precede every key
        let tk: Vec<i32> = (0..m as i32).collect();
        let scale = 1.0 / (c as f64).sqrt();
        let mut scalar = vec![f32::NAN; n * c];
        flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut scalar);
        assert!(scalar.iter().all(|&x| x == 0.0), "scalar: zero, not NaN");
        let mut blocked = vec![f32::NAN; n * c];
        let cfg = KernelConfig::fixed(4, 8, 2);
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut blocked, &cfg);
        assert!(blocked.iter().all(|&x| x == 0.0), "blocked: zero, not NaN");
    }

    #[test]
    fn empty_key_set_yields_zero_rows() {
        let c = 6;
        let q = vec![1.0f32; 3 * c];
        let tq = vec![0i32; 3];
        let mut out = vec![f32::NAN; 3 * c];
        flash_sdpa_blocked(&q, &[], &[], &tq, &[], c, 1.0, &mut out, &KernelConfig::default());
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_skip_table_is_correct() {
        let tk = vec![5, 1, 3, 9, 9, 9, 0, 2];
        let blocks = key_blocks(&tk, 3);
        assert_eq!(blocks.len(), 3);
        assert_eq!((blocks[0].min_tk, blocks[0].max_tk), (1, 5));
        assert_eq!((blocks[1].min_tk, blocks[1].max_tk), (9, 9));
        assert_eq!((blocks[2].min_tk, blocks[2].max_tk), (0, 2));
        assert_eq!((blocks[2].start, blocks[2].end), (6, 8));
    }

    #[test]
    fn lane_variants_agree_with_scalar() {
        let mut rng = Rng::new(31);
        let (n, m, c) = (9, 17, 26); // ragged: c % every lane width != 0
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 2);
        let scale = 1.0 / (c as f64).sqrt();
        let mut want = vec![0.0f32; n * c];
        flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut want);
        for lanes in [4usize, 8, 16] {
            let got = run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(8, lanes, 2));
            for (a, b) in want.iter().zip(got.iter()) {
                assert!((a - b).abs() < 1e-5, "lanes={lanes}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn config_normalization() {
        let cfg = KernelConfig {
            block_m: 0,
            lanes: 7,
            threads: 10_000,
        }
        .normalized();
        assert_eq!(cfg.block_m, 1);
        assert_eq!(cfg.lanes, KernelConfig::DEFAULT_LANES);
        assert_eq!(cfg.threads, 32);
        let d = KernelConfig::default();
        assert!(d.threads >= 1);
        assert!(d.block_m >= 1);
        assert_eq!(KernelConfig::with_threads(0).block_m, d.block_m);
        assert_eq!(KernelConfig::with_threads(3).threads, 3);
    }

    #[test]
    fn scratch_accounting_is_o_c_per_thread() {
        let cfg = KernelConfig::fixed(64, 8, 4);
        let per = cfg.scratch_bytes_per_thread(100, 1000);
        assert_eq!(per, 64 * 8 + 100 * 4 + 100 * 8);
        // block capped by m
        assert_eq!(
            cfg.scratch_bytes_per_thread(100, 16),
            16 * 8 + 100 * 4 + 100 * 8
        );
        // quantized sources add exactly the two c-wide dequant rows
        assert_eq!(
            cfg.scratch_bytes_per_thread_rows(100, 16, true),
            cfg.scratch_bytes_per_thread(100, 16) + 2 * 100 * 4
        );
        assert_eq!(
            cfg.scratch_bytes_per_thread_rows(100, 16, false),
            cfg.scratch_bytes_per_thread(100, 16)
        );
    }

    #[test]
    fn profiling_counters_accumulate_when_enabled() {
        use crate::trace::{KernelProfile, ProfileGuard};
        let mut rng = Rng::new(77);
        let (n, m, c) = (16usize, 32usize, 8usize);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
        let before = KernelProfile::snapshot();
        let _g = ProfileGuard::enable();
        run_blocked(&q, &k, &v, &tq, &tk, c, &KernelConfig::fixed(8, 8, 2));
        let d = KernelProfile::snapshot().delta(&before);
        assert!(d.calls >= 1, "calls: {}", d.calls);
        assert!(d.rows >= n as u64, "rows: {}", d.rows);
        assert!(d.chunks >= 1);
        assert!(d.participants >= 1);
        assert!(d.key_blocks_visited + d.key_blocks_skipped >= 1);
        assert!(d.scratch_bytes > 0);
        // f32 sources never dequantize (no quantized-row reads recorded
        // by THIS call; concurrent tests can only add, not subtract)
    }

    #[test]
    fn quantized_profiling_counts_dequantized_rows() {
        use crate::attention::quant::FeatureRows;
        use crate::config::CachePrecision;
        use crate::trace::{KernelProfile, ProfileGuard};
        let mut rng = Rng::new(78);
        let (n, m, c) = (8usize, 16usize, 8usize);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 4);
        let mut kq = FeatureRows::new(CachePrecision::F16, c);
        kq.push_rows(&k);
        let mut vq = FeatureRows::new(CachePrecision::F16, c);
        vq.push_rows(&v);
        let before = KernelProfile::snapshot();
        let _g = ProfileGuard::enable();
        let mut out = vec![0.0f32; n * c];
        let scale = 1.0 / (c as f64).sqrt();
        flash_sdpa_rows(
            &q,
            kq.as_kv(),
            vq.as_kv(),
            &tq,
            &tk,
            c,
            scale,
            &mut out,
            &KernelConfig::fixed(8, 8, 1),
        );
        let d = KernelProfile::snapshot().delta(&before);
        assert!(d.rows_dequantized >= 1, "dequant rows: {}", d.rows_dequantized);
    }

    #[test]
    fn f32_row_source_is_bit_identical_to_slice_entry_point() {
        use crate::attention::quant::KvRowSource;
        let mut rng = Rng::new(21);
        let (n, m, c) = (9, 23, 18);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let scale = 1.0 / (c as f64).sqrt();
        let cfg = KernelConfig::fixed(7, 8, 2);
        let mut a = vec![0.0f32; n * c];
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut a, &cfg);
        let mut b = vec![0.0f32; n * c];
        flash_sdpa_rows(
            &q,
            KvRowSource::F32(&k),
            KvRowSource::F32(&v),
            &tq,
            &tk,
            c,
            scale,
            &mut b,
            &cfg,
        );
        assert_eq!(a, b, "wrapper and row-source path must agree bitwise");
    }

    #[test]
    fn quantized_row_source_tracks_the_f32_kernel() {
        use crate::attention::quant::FeatureRows;
        use crate::config::CachePrecision;
        let mut rng = Rng::new(22);
        let (n, m, c) = (11, 37, 26);
        let (q, k, v, tq, tk) = rand_inputs(&mut rng, n, m, c, 3);
        let scale = 1.0 / (c as f64).sqrt();
        let cfg = KernelConfig::fixed(8, 8, 2);
        let mut want = vec![0.0f32; n * c];
        flash_sdpa_blocked(&q, &k, &v, &tq, &tk, c, scale, &mut want, &cfg);
        for (codec, tol) in [(CachePrecision::F16, 2e-2f32), (CachePrecision::Bf16, 1e-1)] {
            let mut kq = FeatureRows::new(codec, c);
            kq.push_rows(&k);
            let mut vq = FeatureRows::new(codec, c);
            vq.push_rows(&v);
            let mut got = vec![f32::NAN; n * c];
            flash_sdpa_rows(
                &q,
                kq.as_kv(),
                vq.as_kv(),
                &tq,
                &tk,
                c,
                scale,
                &mut got,
                &cfg,
            );
            for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
                assert!((a - b).abs() < tol, "{codec:?} [{i}]: {a} vs {b}");
            }
        }
        // quantized all-masked rows are still exact zeros, never NaN
        let tq_masked = vec![-10i32; n];
        let kq = {
            let mut s = FeatureRows::new(CachePrecision::F16, c);
            s.push_rows(&k);
            s
        };
        let vq = {
            let mut s = FeatureRows::new(CachePrecision::F16, c);
            s.push_rows(&v);
            s
        };
        let mut out = vec![f32::NAN; n * c];
        flash_sdpa_rows(
            &q,
            kq.as_kv(),
            vq.as_kv(),
            &tq_masked,
            &tk,
            c,
            scale,
            &mut out,
            &cfg,
        );
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
