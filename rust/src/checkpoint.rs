//! Checkpointing: save/restore model parameters (and optimizer state) to a
//! length-prefixed binary format with name/shape validation on load.
//!
//! Checkpoints are method-agnostic — every attention variant shares the
//! same parameter layout (see `python/compile/model.py`) — so a checkpoint
//! trained with one method can warm-start another (useful for the
//! ablations in `rust/benches/`).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{Dtype, HostTensor};

const MAGIC: u32 = 0x5E2A_C4B7;
const VERSION: u32 = 1;

/// A named tensor bundle (parameters, or parameters + Adam moments).
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub method: String,
    pub entries: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    pub fn new(step: u64, method: &str) -> Checkpoint {
        Checkpoint {
            step,
            method: method.to_string(),
            entries: Vec::new(),
        }
    }

    pub fn push(&mut self, name: &str, t: HostTensor) {
        self.entries.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        write_str(&mut w, &self.method)?;
        w.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for (name, t) in &self.entries {
            write_str(&mut w, name)?;
            let dtype_tag: u8 = match t.dtype() {
                Dtype::F32 => 0,
                Dtype::I32 => 1,
            };
            w.write_all(&[dtype_tag])?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            match t.dtype() {
                Dtype::F32 => {
                    for v in t.as_f32()? {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                Dtype::I32 => {
                    for v in t.as_i32()? {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut r = std::io::BufReader::new(f);
        if read_u32(&mut r)? != MAGIC {
            bail!("not a se2attn checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!(
                "checkpoint version {version}, expected {VERSION} — this file was written \
                 by an incompatible se2attn build; re-export it with a build matching this \
                 binary (see `train --save`)"
            );
        }
        let step = read_u64(&mut r)?;
        let method = read_str(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        if n > 1 << 20 {
            bail!("corrupt checkpoint: implausible entry count {n}");
        }
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_str(&mut r)?;
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let rank = read_u32(&mut r)? as usize;
            if rank > 16 {
                bail!("corrupt checkpoint: rank {rank}");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(&mut r)? as usize);
            }
            let numel: usize = shape.iter().product();
            if numel > 1 << 28 {
                bail!("corrupt checkpoint: tensor too large");
            }
            let mut buf = vec![0u8; numel * 4];
            r.read_exact(&mut buf)?;
            let t = match tag[0] {
                0 => HostTensor::f32(
                    shape,
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                1 => HostTensor::i32(
                    shape,
                    buf.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                other => bail!("corrupt checkpoint: dtype tag {other}"),
            };
            entries.push((name, t));
        }
        Ok(Checkpoint {
            step,
            method,
            entries,
        })
    }

    /// Extract the tensors for the given names, in order, erroring on any
    /// missing entry (used to restore `ModelHandle` state).
    pub fn take_ordered(&self, prefix: &str, names: &[String]) -> Result<Vec<HostTensor>> {
        names
            .iter()
            .map(|n| {
                let key = format!("{prefix}{n}");
                self.get(&key)
                    .cloned()
                    .with_context(|| format!("checkpoint missing '{key}'"))
            })
            .collect()
    }
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > 4096 {
        bail!("corrupt checkpoint: string length {n}");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf).context("checkpoint string not utf-8")?)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn sample_checkpoint() -> Checkpoint {
        let mut rng = Rng::new(0);
        let mut ck = Checkpoint::new(123, "se2fourier");
        ck.push(
            "param:embed_w",
            HostTensor::f32(vec![4, 8], rng.normal_vec_f32(32, 1.0)),
        );
        ck.push("param:embed_b", HostTensor::f32(vec![8], vec![0.5; 8]));
        ck.push("meta:ids", HostTensor::i32(vec![3], vec![1, 2, 3]));
        ck
    }

    #[test]
    fn roundtrip() {
        let ck = sample_checkpoint();
        let path = std::env::temp_dir().join("se2attn_ck_test/a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn take_ordered_validates() {
        let ck = sample_checkpoint();
        let names = vec!["embed_w".to_string(), "embed_b".to_string()];
        let got = ck.take_ordered("param:", &names).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].shape, vec![4, 8]);
        let missing = vec!["nope".to_string()];
        assert!(ck.take_ordered("param:", &missing).is_err());
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let dir = std::env::temp_dir().join("se2attn_ck_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"garbage").unwrap();
        assert!(Checkpoint::load(&bad).is_err());
        // truncation fuzz
        let good = dir.join("good.ckpt");
        sample_checkpoint().save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let cut = rng.below(bytes.len());
            std::fs::write(&bad, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&bad).is_err(), "cut at {cut}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_fails_with_actionable_message() {
        let dir = std::env::temp_dir().join("se2attn_ck_skew");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("skew.ckpt");
        sample_checkpoint().save(&path).unwrap();
        // bump the on-disk version field (bytes 4..8, after the magic)
        // to simulate a file written by a future build
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&format!("checkpoint version {}, expected {VERSION}", VERSION + 1)),
            "message must name both versions: {msg}"
        );
        assert!(
            msg.contains("re-export"),
            "message must say what to do about it: {msg}"
        );
        // a matching version with a mangled magic stays a distinct error
        let mut bad_magic = std::fs::read(&path).unwrap();
        bad_magic[4..8].copy_from_slice(&VERSION.to_le_bytes());
        bad_magic[0] ^= 0xFF;
        std::fs::write(&path, &bad_magic).unwrap();
        let msg = format!("{:#}", Checkpoint::load(&path).unwrap_err());
        assert!(msg.contains("bad magic"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
