//! Benchmark harness (substrate for the absent `criterion` crate).
//!
//! Provides warmup + timed iterations with robust statistics, paper-style
//! table printing, and JSON row export so EXPERIMENTS.md numbers are
//! regenerable byte-for-byte.  Every `cargo bench` target in this repo is a
//! `harness = false` binary built on this module.

use std::time::{Duration, Instant};

use crate::jsonio::Json;

/// Summary statistics over timed iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean_ns: mean,
            p50_ns: percentile(&ns, 50.0),
            p95_ns: percentile(&ns, 95.0),
            min_ns: ns[0],
            max_ns: ns[n - 1],
            std_ns: var.sqrt(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("max_ns", Json::Num(self.max_ns)),
            ("std_ns", Json::Num(self.std_ns)),
        ])
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Time `f` with warmup; stops after `max_iters` iterations or
/// `max_time` of measurement, whichever first (min 5 iterations).
pub fn bench<F: FnMut()>(warmup: usize, max_iters: usize, max_time: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters);
    let start = Instant::now();
    for i in 0..max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if i >= 4 && start.elapsed() > max_time {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Convenience: 3 warmup iterations, <=50 iterations, <=5 s.
pub fn bench_quick<F: FnMut()>(f: F) -> Stats {
    bench(3, 50, Duration::from_secs(5), f)
}

/// How much work a bench run should do.  `Smoke` (env
/// `SE2ATTN_BENCH_SMOKE=1`) is the CI perf-regression gate: small sizes,
/// few iterations, JSON rows still emitted so the trajectory is archived
/// per commit.  `Full` (env `SE2ATTN_BENCH_FULL=1`) is the paper-scale
/// sweep; `Default` is the local developer run.  Smoke wins if both env
/// vars are set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchMode {
    Smoke,
    Default,
    Full,
}

impl BenchMode {
    pub fn from_env() -> BenchMode {
        let on = |name: &str| {
            std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
        };
        if on("SE2ATTN_BENCH_SMOKE") {
            BenchMode::Smoke
        } else if on("SE2ATTN_BENCH_FULL") {
            BenchMode::Full
        } else {
            BenchMode::Default
        }
    }

    pub fn is_smoke(self) -> bool {
        self == BenchMode::Smoke
    }

    pub fn is_full(self) -> bool {
        self == BenchMode::Full
    }

    /// Pick the mode's variant of a size/iteration list.
    pub fn pick<'a, T>(self, smoke: &'a [T], default: &'a [T], full: &'a [T]) -> &'a [T] {
        match self {
            BenchMode::Smoke => smoke,
            BenchMode::Default => default,
            BenchMode::Full => full,
        }
    }
}

/// Mode-scaled timing: smoke runs 1 warmup + <=8 iters in <=500 ms so the
/// CI gate finishes in seconds; other modes defer to [`bench_quick`].
pub fn bench_mode<F: FnMut()>(mode: BenchMode, f: F) -> Stats {
    match mode {
        BenchMode::Smoke => bench(1, 8, Duration::from_millis(500), f),
        _ => bench_quick(f),
    }
}

/// Write one whole-run JSON document (`{"rows": [...]}`) — the
/// `BENCH_<name>.json` artifacts the CI perf-smoke job uploads.  Unlike
/// [`record_row`]'s append-only `.jsonl`, this file is overwritten per
/// run so each CI run archives exactly its own rows.  Errors propagate:
/// a bench that cannot archive its rows must exit nonzero, not go green
/// with the perf trajectory silently missing.
pub fn write_bench_json(path: &str, rows: Vec<Json>) -> std::io::Result<()> {
    let doc = Json::obj(vec![("rows", Json::Arr(rows))]);
    std::fs::write(path, format!("{doc}\n"))
}

/// Markdown table builder for [`render_bench_report`].
fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = format!("| {} |\n", headers.join(" | "));
    s.push_str(&format!(
        "|{}\n",
        headers.iter().map(|_| "---|").collect::<String>()
    ));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

fn row_num(row: &Json, key: &str) -> Option<f64> {
    row.get(key).and_then(Json::as_f64).filter(|x| x.is_finite())
}

fn doc_rows(doc: &Json) -> Vec<&Json> {
    doc.get("rows")
        .and_then(Json::as_arr)
        .map(|r| r.iter().collect())
        .unwrap_or_default()
}

/// Render the README "Benchmarks" section from the `BENCH_attention.json`
/// / `BENCH_decode.json` / `BENCH_serving.json` documents the benches
/// write (and the CI perf-smoke job uploads) — the `se2attn
/// bench-report` CLI command, so README performance numbers are
/// generated from archived measurements instead of hand-written claims.
/// Any document may be absent; a note is emitted for whatever is
/// missing.
pub fn render_bench_report(
    attention: Option<&Json>,
    decode: Option<&Json>,
    serving: Option<&Json>,
) -> String {
    let mut out = String::from(
        "## Benchmarks\n\n\
         <!-- generated by `se2-attention bench-report` from \
         BENCH_attention.json / BENCH_decode.json / BENCH_serving.json \
         (written by `cargo bench --bench attention_throughput` / \
         `--bench decode_throughput` / `--bench serving_load`, uploaded \
         by the CI perf-smoke job). Do not hand-edit the tables. -->\n\n",
    );

    match attention {
        None => out.push_str("*BENCH_attention.json not found — run `cargo bench --bench attention_throughput` first.*\n\n"),
        Some(doc) => {
            let rows = doc_rows(doc);
            let kernel: Vec<Vec<String>> = rows
                .iter()
                .filter(|r| r.get("bench").and_then(|b| b.as_str()) == Some("kernel"))
                .filter_map(|r| {
                    let scalar = r.get("scalar").and_then(|s| row_num(s, "mean_ns"))?;
                    let b4 = r.get("blocked_t4").and_then(|s| row_num(s, "mean_ns"))?;
                    Some(vec![
                        format!("{}", row_num(r, "n")? as u64),
                        format!("{}", row_num(r, "c")? as u64),
                        format!("{:.3}", scalar / 1e6),
                        format!("{:.3}", b4 / 1e6),
                        format!("{:.2}x", row_num(r, "speedup_t4")?),
                    ])
                })
                .collect();
            if !kernel.is_empty() {
                out.push_str("### Blocked flash kernel vs scalar oracle (se2fourier)\n\n");
                out.push_str(&md_table(
                    &["N=M", "c", "scalar ms", "blocked x4 ms", "speedup"],
                    &kernel,
                ));
                out.push('\n');
            }
            let fused: Vec<Vec<String>> = rows
                .iter()
                .filter(|r| r.get("bench").and_then(|b| b.as_str()) == Some("fused"))
                .filter_map(|r| {
                    let projected =
                        r.get("projected").and_then(|s| row_num(s, "mean_ns"))?;
                    let fus = r.get("fused").and_then(|s| row_num(s, "mean_ns"))?;
                    Some(vec![
                        format!("{}", row_num(r, "m")? as u64),
                        format!("{}", row_num(r, "n_new")? as u64),
                        format!("{:.3}", projected / 1e6),
                        format!("{:.3}", fus / 1e6),
                        format!("{:.2}x", row_num(r, "speedup")?),
                    ])
                })
                .collect();
            if !fused.is_empty() {
                out.push_str(
                    "### Fused projection vs project-then-attend (se2fourier decode shapes)\n\n",
                );
                out.push_str(&md_table(
                    &["keys m", "new rows", "project+attend ms", "fused ms", "speedup"],
                    &fused,
                ));
                out.push('\n');
            }
            let algo: Vec<Vec<String>> = rows
                .iter()
                .filter(|r| {
                    r.get("bench").and_then(|b| b.as_str()) == Some("algorithms")
                        && r.get("method").and_then(|m| m.as_str()) == Some("se2fourier")
                })
                .filter_map(|r| {
                    let lin = row_num(r, "linear_ms")?;
                    let quad = row_num(r, "quadratic_ms");
                    Some(vec![
                        format!("{}", row_num(r, "n")? as u64),
                        format!("{lin:.3}"),
                        quad.map_or("-".into(), |q| format!("{q:.3}")),
                        quad.map_or("-".into(), |q| format!("{:.1}x", q / lin)),
                    ])
                })
                .collect();
            if !algo.is_empty() {
                out.push_str("### Algorithm 2 (linear) vs Algorithm 1 (quadratic), se2fourier\n\n");
                out.push_str(&md_table(
                    &["N", "linear ms", "quadratic ms", "quad/lin"],
                    &algo,
                ));
                out.push('\n');
            }
        }
    }

    match decode {
        None => out.push_str("*BENCH_decode.json not found — run `cargo bench --bench decode_throughput` first.*\n\n"),
        Some(doc) => {
            let rows = doc_rows(doc);
            let attn: Vec<Vec<String>> = rows
                .iter()
                .filter(|r| r.get("path").and_then(|p| p.as_str()) == Some("attention"))
                .filter_map(|r| {
                    Some(vec![
                        format!("{}", row_num(r, "window")? as u64),
                        format!("{:.3}", row_num(r, "full_ms")?),
                        format!("{:.3}", row_num(r, "cached_ms")?),
                        format!("{:.2}x", row_num(r, "speedup")?),
                    ])
                })
                .collect();
            if !attn.is_empty() {
                out.push_str("### Incremental decode: cached vs full-recompute per step\n\n");
                out.push_str(&md_table(
                    &["window", "full ms/step", "cached ms/step", "speedup"],
                    &attn,
                ));
                out.push('\n');
            }
            let bytes: Vec<Vec<String>> = rows
                .iter()
                .filter(|r| r.get("path").and_then(|p| p.as_str()) == Some("cache_precision"))
                .filter_map(|r| {
                    Some(vec![
                        format!("{}", row_num(r, "window")? as u64),
                        format!("{}", row_num(r, "f32_bytes")? as u64),
                        format!("{}", row_num(r, "f16_bytes")? as u64),
                        format!("{:.0}%", row_num(r, "ratio")? * 100.0),
                    ])
                })
                .collect();
            if !bytes.is_empty() {
                out.push_str("### Quantized KV cache: resident bytes, f16 vs f32\n\n");
                out.push_str(&md_table(
                    &["window", "f32 bytes", "f16 bytes", "f16/f32"],
                    &bytes,
                ));
                out.push('\n');
            }
            if let Some(tok) = rows
                .iter()
                .find(|r| r.get("path").and_then(|p| p.as_str()) == Some("tokenization"))
            {
                if let (Some(full), Some(cached), Some(sp)) = (
                    row_num(tok, "full_us"),
                    row_num(tok, "cached_us"),
                    row_num(tok, "speedup"),
                ) {
                    out.push_str(&format!(
                        "Tokenization path: full `tokenize_window` {full:.1} us/step vs \
                         cached pool hit {cached:.1} us/step ({sp:.2}x).\n\n"
                    ));
                }
            }
        }
    }

    match serving {
        None => out.push_str("*BENCH_serving.json not found — run `cargo bench --bench serving_load` first.*\n\n"),
        Some(doc) => {
            let rows = doc_rows(doc);
            let load: Vec<Vec<String>> = rows
                .iter()
                .filter_map(|r| {
                    Some(vec![
                        r.get("mode").and_then(|m| m.as_str())?.to_string(),
                        format!("{:.1}x", row_num(r, "load_factor")?),
                        format!("{:.1}", row_num(r, "offered_rps")?),
                        format!("{:.1}", row_num(r, "goodput_rps")?),
                        format!("{:.1}", row_num(r, "p50_ms")?),
                        format!("{:.1}", row_num(r, "p99_ms")?),
                        format!("{:.1}", row_num(r, "p999_ms")?),
                        format!("{}", row_num(r, "shed")? as u64),
                        format!("{}", row_num(r, "rejected")? as u64),
                    ])
                })
                .collect();
            if !load.is_empty() {
                out.push_str(
                    "### Serving under load: continuous batching vs fixed batcher\n\n",
                );
                out.push_str(&md_table(
                    &[
                        "mode", "load", "offered rps", "goodput rps", "p50 ms", "p99 ms",
                        "p999 ms", "shed", "rejected",
                    ],
                    &load,
                ));
                if let Some(slo) = rows.first().and_then(|r| row_num(r, "slo_ms")) {
                    out.push_str(&format!(
                        "\nGoodput counts completions inside the {slo:.0} ms end-to-end \
                         SLO; open-loop Poisson arrivals, one worker shard per mode.\n",
                    ));
                }
                out.push('\n');
            }
        }
    }
    out
}

/// Shape/identity keys that pair a row with its baseline counterpart in
/// [`compare_bench_reports`].  Everything else in a row is treated as a
/// measurement, never as identity — so two runs of the same bench matrix
/// always pair up even when every timing moved.
const IDENTITY_KEYS: &[&str] = &[
    "bench",
    "path",
    "mode",
    "method",
    "kind",
    "n",
    "m",
    "c",
    "n_new",
    "window",
    "threads",
    "load_factor",
    "precision",
];

/// Stable identity string of a bench row (`None` for rows with no
/// identity fields at all — those are skipped rather than mispaired).
fn row_identity(row: &Json) -> Option<String> {
    let mut parts = Vec::new();
    for k in IDENTITY_KEYS {
        match row.get(k) {
            Some(Json::Str(s)) => parts.push(format!("{k}={s}")),
            Some(Json::Num(x)) => parts.push(format!("{k}={x}")),
            _ => {}
        }
    }
    if parts.is_empty() {
        None
    } else {
        Some(parts.join(" "))
    }
}

/// Gating direction of a metric key: `Some(true)` when lower is better
/// (latencies), `Some(false)` when higher is better (throughput and
/// speedup ratios), `None` for non-gated values (byte counts, identity
/// fields, offered load — which the harness chooses, not earns).
fn metric_lower_is_better(key: &str) -> Option<bool> {
    if key.ends_with("_ms") || key.ends_with("_us") || key.ends_with("_ns") {
        Some(true)
    } else if key == "goodput_rps" || key.starts_with("speedup") {
        Some(false)
    } else {
        None
    }
}

/// Allowed relative loss before the comparison fails: >10% regression in
/// any gated metric (the CI `bench-regression` job's contract).
const REGRESSION_TOLERANCE: f64 = 0.10;

/// Gated metrics of one row: `(key, value, lower_is_better)` — top-level
/// latency/throughput numbers plus every nested stats object's `mean_ns`.
fn row_metrics(row: &Json) -> Vec<(String, f64, bool)> {
    let Json::Obj(fields) = row else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for (k, v) in fields {
        match v {
            Json::Num(x) if x.is_finite() => {
                if let Some(lower) = metric_lower_is_better(k) {
                    out.push((k.clone(), *x, lower));
                }
            }
            Json::Obj(_) => {
                if let Some(mean) = row_num(v, "mean_ns") {
                    out.push((format!("{k}.mean_ns"), mean, true));
                }
            }
            _ => {}
        }
    }
    out
}

/// Diff two `BENCH_*.json` documents (`{"rows": [...]}`): pair rows by
/// identity, compare every gated metric, and render a markdown delta
/// table.  Returns `(markdown, regressed)` where `regressed` is true iff
/// any gated metric moved more than [`REGRESSION_TOLERANCE`] in the bad
/// direction.  One-sided rows (new in this run, or gone from it) are
/// reported but never fail the comparison — bench matrices are allowed
/// to grow.
pub fn compare_bench_reports(old: &Json, new: &Json) -> (String, bool) {
    use std::collections::BTreeMap;
    let index = |doc: &Json| -> BTreeMap<String, Json> {
        doc_rows(doc)
            .into_iter()
            .filter_map(|r| row_identity(r).map(|id| (id, r.clone())))
            .collect()
    };
    let old_rows = index(old);
    let new_rows = index(new);

    let mut table = Vec::new();
    let mut notes = Vec::new();
    let mut regressed = false;
    for (id, new_row) in &new_rows {
        let Some(old_row) = old_rows.get(id) else {
            notes.push(format!("- `{id}`: new row, no baseline"));
            continue;
        };
        let old_metrics: BTreeMap<String, (f64, bool)> = row_metrics(old_row)
            .into_iter()
            .map(|(k, v, l)| (k, (v, l)))
            .collect();
        for (key, new_val, lower) in row_metrics(new_row) {
            let Some(&(old_val, _)) = old_metrics.get(&key) else {
                continue;
            };
            if old_val == 0.0 {
                continue;
            }
            let delta = new_val / old_val - 1.0;
            // loss > 0 means the metric moved in the bad direction
            let loss = if lower { delta } else { -delta };
            let bad = loss > REGRESSION_TOLERANCE;
            regressed |= bad;
            table.push(vec![
                id.clone(),
                key,
                format!("{old_val:.4}"),
                format!("{new_val:.4}"),
                format!("{:+.1}%", delta * 100.0),
                if bad { "**REGRESSED**".into() } else { "ok".to_string() },
            ]);
        }
    }
    for id in old_rows.keys() {
        if !new_rows.contains_key(id) {
            notes.push(format!("- `{id}`: baseline row missing from this run"));
        }
    }

    let mut md = String::from("### Bench comparison (old -> new)\n\n");
    if table.is_empty() {
        md.push_str("*No paired rows to compare.*\n");
    } else {
        md.push_str(&md_table(
            &["row", "metric", "old", "new", "delta", "status"],
            &table,
        ));
    }
    if !notes.is_empty() {
        md.push('\n');
        md.push_str(&notes.join("\n"));
        md.push('\n');
    }
    md.push_str(&format!(
        "\nGate: fail when any gated metric regresses more than {:.0}%.\n",
        REGRESSION_TOLERANCE * 100.0
    ));
    (md, regressed)
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Append a JSON result row to `bench_results/<bench>.jsonl` (created on
/// demand) so EXPERIMENTS.md can cite exact numbers.
pub fn record_row(bench: &str, row: Json) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{bench}.jsonl"));
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{row}");
    }
}

/// Best-effort peak-RSS reading (linux /proc/self/status, kB).
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean_ns, 3.0);
        assert_eq!(s.p50_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0;
        let s = bench(1, 10, Duration::from_secs(1), || {
            count += 1;
        });
        assert!(s.iters >= 5);
        assert!(count >= s.iters);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // visual; just must not panic
    }

    #[test]
    fn peak_rss_available_on_linux() {
        assert!(peak_rss_kb().unwrap_or(0) > 0);
    }

    #[test]
    fn bench_mode_picks_size_lists() {
        assert_eq!(BenchMode::Smoke.pick(&[1], &[2], &[3]), &[1]);
        assert_eq!(BenchMode::Default.pick(&[1], &[2], &[3]), &[2]);
        assert_eq!(BenchMode::Full.pick(&[1], &[2], &[3]), &[3]);
        assert!(BenchMode::Smoke.is_smoke() && !BenchMode::Smoke.is_full());
    }

    #[test]
    fn bench_mode_smoke_is_bounded() {
        let s = bench_mode(BenchMode::Smoke, || {});
        assert!(s.iters >= 5 && s.iters <= 8, "{}", s.iters);
    }

    #[test]
    fn bench_report_renders_known_rows_and_flags_missing_inputs() {
        let attention = Json::obj(vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("bench", Json::Str("kernel".into())),
                ("n", Json::Num(1024.0)),
                ("c", Json::Num(400.0)),
                (
                    "scalar",
                    Json::obj(vec![("mean_ns", Json::Num(4.0e6))]),
                ),
                (
                    "blocked_t4",
                    Json::obj(vec![("mean_ns", Json::Num(1.0e6))]),
                ),
                ("speedup_t4", Json::Num(4.0)),
            ])]),
        )]);
        let decode = Json::obj(vec![(
            "rows",
            Json::Arr(vec![
                Json::obj(vec![
                    ("path", Json::Str("attention".into())),
                    ("window", Json::Num(64.0)),
                    ("full_ms", Json::Num(2.0)),
                    ("cached_ms", Json::Num(0.5)),
                    ("speedup", Json::Num(4.0)),
                ]),
                Json::obj(vec![
                    ("path", Json::Str("cache_precision".into())),
                    ("window", Json::Num(64.0)),
                    ("f32_bytes", Json::Num(1000.0)),
                    ("f16_bytes", Json::Num(510.0)),
                    ("ratio", Json::Num(0.51)),
                ]),
            ]),
        )]);
        let serving = Json::obj(vec![(
            "rows",
            Json::Arr(vec![
                Json::obj(vec![
                    ("mode", Json::Str("continuous".into())),
                    ("load_factor", Json::Num(2.0)),
                    ("offered_rps", Json::Num(200.0)),
                    ("goodput_rps", Json::Num(95.5)),
                    ("p50_ms", Json::Num(12.0)),
                    ("p99_ms", Json::Num(31.0)),
                    ("p999_ms", Json::Num(40.0)),
                    ("shed", Json::Num(50.0)),
                    ("rejected", Json::Num(0.0)),
                    ("slo_ms", Json::Num(48.0)),
                ]),
                Json::obj(vec![
                    ("mode", Json::Str("fixed".into())),
                    ("load_factor", Json::Num(2.0)),
                    ("offered_rps", Json::Num(200.0)),
                    ("goodput_rps", Json::Num(20.1)),
                    ("p50_ms", Json::Num(300.0)),
                    ("p99_ms", Json::Num(900.0)),
                    ("p999_ms", Json::Num(950.0)),
                    ("shed", Json::Num(0.0)),
                    ("rejected", Json::Num(40.0)),
                    ("slo_ms", Json::Num(48.0)),
                ]),
            ]),
        )]);
        let md = render_bench_report(Some(&attention), Some(&decode), Some(&serving));
        assert!(md.contains("## Benchmarks"), "{md}");
        assert!(md.contains("| 1024 | 400 | 4.000 | 1.000 | 4.00x |"), "{md}");
        assert!(md.contains("| 64 | 2.000 | 0.500 | 4.00x |"), "{md}");
        assert!(md.contains("| 64 | 1000 | 510 | 51% |"), "{md}");
        assert!(md.contains("Serving under load"), "{md}");
        assert!(
            md.contains("| continuous | 2.0x | 200.0 | 95.5 | 12.0 | 31.0 | 40.0 | 50 | 0 |"),
            "{md}"
        );
        assert!(
            md.contains("| fixed | 2.0x | 200.0 | 20.1 | 300.0 | 900.0 | 950.0 | 0 | 40 |"),
            "{md}"
        );
        assert!(md.contains("48 ms end-to-end"), "{md}");
        assert!(md.contains("generated by"), "{md}");
        // missing inputs are called out, not silently dropped
        let md = render_bench_report(None, None, None);
        assert!(md.contains("BENCH_attention.json not found"), "{md}");
        assert!(md.contains("BENCH_decode.json not found"), "{md}");
        assert!(md.contains("BENCH_serving.json not found"), "{md}");
    }

    fn fused_doc(fused_mean_ns: f64) -> Json {
        Json::obj(vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("bench", Json::Str("fused".into())),
                ("m", Json::Num(4096.0)),
                ("n_new", Json::Num(8.0)),
                (
                    "projected",
                    Json::obj(vec![("mean_ns", Json::Num(3.0e6))]),
                ),
                ("fused", Json::obj(vec![("mean_ns", Json::Num(fused_mean_ns))])),
                ("speedup", Json::Num(3.0e6 / fused_mean_ns)),
            ])]),
        )])
    }

    #[test]
    fn bench_report_renders_fused_section() {
        let md = render_bench_report(Some(&fused_doc(1.0e6)), None, None);
        assert!(md.contains("Fused projection vs project-then-attend"), "{md}");
        assert!(md.contains("| 4096 | 8 | 3.000 | 1.000 | 3.00x |"), "{md}");
    }

    #[test]
    fn compare_flags_regressions_over_tolerance_only() {
        // identical runs: no regression
        let (md, bad) = compare_bench_reports(&fused_doc(1.0e6), &fused_doc(1.0e6));
        assert!(!bad, "{md}");
        assert!(md.contains("fused.mean_ns"), "{md}");
        // 5% slower: inside the 10% tolerance
        let (_, bad) = compare_bench_reports(&fused_doc(1.0e6), &fused_doc(1.05e6));
        assert!(!bad);
        // 20% slower: regression (both the mean_ns and the derived
        // speedup cross the gate)
        let (md, bad) = compare_bench_reports(&fused_doc(1.0e6), &fused_doc(1.2e6));
        assert!(bad, "{md}");
        assert!(md.contains("**REGRESSED**"), "{md}");
        // 20% *faster* is an improvement, not a regression
        let (_, bad) = compare_bench_reports(&fused_doc(1.0e6), &fused_doc(0.8e6));
        assert!(!bad);
    }

    #[test]
    fn compare_tolerates_one_sided_rows() {
        let empty = Json::obj(vec![("rows", Json::Arr(vec![]))]);
        let (md, bad) = compare_bench_reports(&empty, &fused_doc(1.0e6));
        assert!(!bad, "new rows must not fail the gate: {md}");
        assert!(md.contains("no baseline"), "{md}");
        let (md, bad) = compare_bench_reports(&fused_doc(1.0e6), &empty);
        assert!(!bad, "removed rows must not fail the gate: {md}");
        assert!(md.contains("missing from this run"), "{md}");
    }

    #[test]
    fn compare_pairs_rows_by_identity_not_position() {
        let two = |a: f64, b: f64| {
            Json::obj(vec![(
                "rows",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("bench", Json::Str("fused".into())),
                        ("m", Json::Num(1024.0)),
                        ("fused", Json::obj(vec![("mean_ns", Json::Num(a))])),
                    ]),
                    Json::obj(vec![
                        ("bench", Json::Str("fused".into())),
                        ("m", Json::Num(4096.0)),
                        ("fused", Json::obj(vec![("mean_ns", Json::Num(b))])),
                    ]),
                ]),
            )])
        };
        // same values, opposite row order in the baseline: must pair by
        // (bench, m) identity and find nothing regressed
        let old = two(2.0e6, 8.0e6);
        let new = Json::obj(vec![(
            "rows",
            Json::Arr(doc_rows(&two(2.0e6, 8.0e6)).into_iter().rev().cloned().collect()),
        )]);
        let (md, bad) = compare_bench_reports(&old, &new);
        assert!(!bad, "{md}");
    }

    #[test]
    fn write_bench_json_roundtrips() {
        let dir = std::env::temp_dir().join("se2attn_benchlib_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_test.json");
        let path = path.to_str().unwrap();
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0]);
        write_bench_json(path, vec![Json::obj(vec![("stats", s.to_json())])]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let rows = doc.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        let mean = rows[0]
            .get("stats")
            .and_then(|s| s.get("mean_ns"))
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(mean, 2.0);
    }
}
