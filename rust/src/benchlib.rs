//! Benchmark harness (substrate for the absent `criterion` crate).
//!
//! Provides warmup + timed iterations with robust statistics, paper-style
//! table printing, and JSON row export so EXPERIMENTS.md numbers are
//! regenerable byte-for-byte.  Every `cargo bench` target in this repo is a
//! `harness = false` binary built on this module.

use std::time::{Duration, Instant};

use crate::jsonio::Json;

/// Summary statistics over timed iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean_ns: mean,
            p50_ns: percentile(&ns, 50.0),
            p95_ns: percentile(&ns, 95.0),
            min_ns: ns[0],
            max_ns: ns[n - 1],
            std_ns: var.sqrt(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("max_ns", Json::Num(self.max_ns)),
            ("std_ns", Json::Num(self.std_ns)),
        ])
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Time `f` with warmup; stops after `max_iters` iterations or
/// `max_time` of measurement, whichever first (min 5 iterations).
pub fn bench<F: FnMut()>(warmup: usize, max_iters: usize, max_time: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters);
    let start = Instant::now();
    for i in 0..max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if i >= 4 && start.elapsed() > max_time {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Convenience: 3 warmup iterations, <=50 iterations, <=5 s.
pub fn bench_quick<F: FnMut()>(f: F) -> Stats {
    bench(3, 50, Duration::from_secs(5), f)
}

/// How much work a bench run should do.  `Smoke` (env
/// `SE2ATTN_BENCH_SMOKE=1`) is the CI perf-regression gate: small sizes,
/// few iterations, JSON rows still emitted so the trajectory is archived
/// per commit.  `Full` (env `SE2ATTN_BENCH_FULL=1`) is the paper-scale
/// sweep; `Default` is the local developer run.  Smoke wins if both env
/// vars are set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchMode {
    Smoke,
    Default,
    Full,
}

impl BenchMode {
    pub fn from_env() -> BenchMode {
        let on = |name: &str| {
            std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
        };
        if on("SE2ATTN_BENCH_SMOKE") {
            BenchMode::Smoke
        } else if on("SE2ATTN_BENCH_FULL") {
            BenchMode::Full
        } else {
            BenchMode::Default
        }
    }

    pub fn is_smoke(self) -> bool {
        self == BenchMode::Smoke
    }

    pub fn is_full(self) -> bool {
        self == BenchMode::Full
    }

    /// Pick the mode's variant of a size/iteration list.
    pub fn pick<'a, T>(self, smoke: &'a [T], default: &'a [T], full: &'a [T]) -> &'a [T] {
        match self {
            BenchMode::Smoke => smoke,
            BenchMode::Default => default,
            BenchMode::Full => full,
        }
    }
}

/// Mode-scaled timing: smoke runs 1 warmup + <=8 iters in <=500 ms so the
/// CI gate finishes in seconds; other modes defer to [`bench_quick`].
pub fn bench_mode<F: FnMut()>(mode: BenchMode, f: F) -> Stats {
    match mode {
        BenchMode::Smoke => bench(1, 8, Duration::from_millis(500), f),
        _ => bench_quick(f),
    }
}

/// Write one whole-run JSON document (`{"rows": [...]}`) — the
/// `BENCH_<name>.json` artifacts the CI perf-smoke job uploads.  Unlike
/// [`record_row`]'s append-only `.jsonl`, this file is overwritten per
/// run so each CI run archives exactly its own rows.  Errors propagate:
/// a bench that cannot archive its rows must exit nonzero, not go green
/// with the perf trajectory silently missing.
pub fn write_bench_json(path: &str, rows: Vec<Json>) -> std::io::Result<()> {
    let doc = Json::obj(vec![("rows", Json::Arr(rows))]);
    std::fs::write(path, format!("{doc}\n"))
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Append a JSON result row to `bench_results/<bench>.jsonl` (created on
/// demand) so EXPERIMENTS.md can cite exact numbers.
pub fn record_row(bench: &str, row: Json) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{bench}.jsonl"));
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{row}");
    }
}

/// Best-effort peak-RSS reading (linux /proc/self/status, kB).
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean_ns, 3.0);
        assert_eq!(s.p50_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0;
        let s = bench(1, 10, Duration::from_secs(1), || {
            count += 1;
        });
        assert!(s.iters >= 5);
        assert!(count >= s.iters);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // visual; just must not panic
    }

    #[test]
    fn peak_rss_available_on_linux() {
        assert!(peak_rss_kb().unwrap_or(0) > 0);
    }

    #[test]
    fn bench_mode_picks_size_lists() {
        assert_eq!(BenchMode::Smoke.pick(&[1], &[2], &[3]), &[1]);
        assert_eq!(BenchMode::Default.pick(&[1], &[2], &[3]), &[2]);
        assert_eq!(BenchMode::Full.pick(&[1], &[2], &[3]), &[3]);
        assert!(BenchMode::Smoke.is_smoke() && !BenchMode::Smoke.is_full());
    }

    #[test]
    fn bench_mode_smoke_is_bounded() {
        let s = bench_mode(BenchMode::Smoke, || {});
        assert!(s.iters >= 5 && s.iters <= 8, "{}", s.iters);
    }

    #[test]
    fn write_bench_json_roundtrips() {
        let dir = std::env::temp_dir().join("se2attn_benchlib_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_test.json");
        let path = path.to_str().unwrap();
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0]);
        write_bench_json(path, vec![Json::obj(vec![("stats", s.to_json())])]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let rows = doc.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        let mean = rows[0]
            .get("stats")
            .and_then(|s| s.get("mean_ns"))
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(mean, 2.0);
    }
}
