//! Dataset pipeline: scenario -> tokenized training examples, binary shard
//! format, deterministic shuffling, batching, train/val split.
//!
//! Shards are a simple length-prefixed binary format (magic + header +
//! per-example arrays) — no serde dependency, write/read round-trip is
//! property-tested.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::SimConfig;
use crate::prng::Rng;
use crate::sim::suite::{FamilyId, MixGenerator, WorkloadMix};
use crate::tokenizer::{TokenizedScene, Tokenizer};

const MAGIC: u32 = 0x5E2A_77E5;
const VERSION: u32 = 3;

/// One training example (a tokenized scene).
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub feat: Vec<f32>,
    pub pose: Vec<f32>,
    pub tq: Vec<i32>,
    pub target: Vec<i32>,
    /// Scenario seed + window offset, for tracing examples to scenarios.
    pub scenario_seed: u64,
    pub t0: u32,
    /// Scenario family tag ([`FamilyId::index`]) for per-family curricula
    /// and evaluation splits.
    pub family: u32,
}

impl Example {
    pub fn from_scene(ts: &TokenizedScene, seed: u64, t0: usize, family: FamilyId) -> Example {
        Example {
            feat: ts.feat.clone(),
            pose: ts.pose.clone(),
            tq: ts.tq.clone(),
            target: ts.target.clone(),
            scenario_seed: seed,
            t0: t0 as u32,
            family: family.index() as u32,
        }
    }

    /// The family tag decoded (corrupt/foreign tags fall back to the
    /// legacy corridor family).
    pub fn family_id(&self) -> FamilyId {
        FamilyId::from_index(self.family as usize).unwrap_or(FamilyId::Corridor)
    }
}

/// A batch in model layout: (B, N, ...) row-major flat arrays.
#[derive(Clone, Debug)]
pub struct Batch {
    pub feat: Vec<f32>,
    pub pose: Vec<f32>,
    pub tq: Vec<i32>,
    pub target: Vec<i32>,
    pub batch_size: usize,
}

pub fn collate(examples: &[&Example]) -> Batch {
    let b = examples.len();
    let mut batch = Batch {
        feat: Vec::with_capacity(b * examples[0].feat.len()),
        pose: Vec::with_capacity(b * examples[0].pose.len()),
        tq: Vec::with_capacity(b * examples[0].tq.len()),
        target: Vec::with_capacity(b * examples[0].target.len()),
        batch_size: b,
    };
    for e in examples {
        batch.feat.extend_from_slice(&e.feat);
        batch.pose.extend_from_slice(&e.pose);
        batch.tq.extend_from_slice(&e.tq);
        batch.target.extend_from_slice(&e.target);
    }
    batch
}

/// Generate `n_examples` examples from legacy corridor scenarios
/// `seed_start..` (see [`generate_examples_mix`] for the family-mixed
/// pipeline), taking several windows per scenario.
pub fn generate_examples(
    sim: &SimConfig,
    tokenizer: &Tokenizer,
    seed_start: u64,
    n_examples: usize,
) -> Vec<Example> {
    generate_examples_mix(
        sim,
        tokenizer,
        &WorkloadMix::single(FamilyId::Corridor),
        seed_start,
        n_examples,
    )
}

/// Generate `n_examples` family-tagged examples from a weighted workload
/// mix: each scenario seed draws its family deterministically from `mix`,
/// then contributes several windows (every other step of the usable
/// range).  Shards produced from the same (mix, seed, n) are bit-identical.
pub fn generate_examples_mix(
    sim: &SimConfig,
    tokenizer: &Tokenizer,
    mix: &WorkloadMix,
    seed_start: u64,
    n_examples: usize,
) -> Vec<Example> {
    let gen = MixGenerator::new(sim.clone(), mix.clone());
    let mut out = Vec::with_capacity(n_examples);
    let mut seed = seed_start;
    let h = sim.history_steps;
    while out.len() < n_examples {
        let s = gen.generate(seed);
        // usable t0 range: [h-1, h-1+future) stepping by 2 for diversity
        let mut t0 = h - 1;
        while t0 < h - 1 + sim.future_steps && out.len() < n_examples {
            let ts = tokenizer.tokenize_scenario(&s, t0);
            out.push(Example::from_scene(&ts, seed, t0, s.family));
            t0 += 2;
        }
        seed += 1;
    }
    out
}

// --------------------------------------------------------------------------
// data augmentation (paper Sec. V: "ablation experiments comparing our
// method against other approaches, such as data augmentation")
// --------------------------------------------------------------------------

/// Apply a random global SE(2) frame jitter to an example's poses — the
/// classical alternative to invariant architectures: teach a non-invariant
/// model (e.g. `abs`) approximate invariance by randomizing the frame.
///
/// Features are untouched (they are frame-invariant by construction);
/// only the pose channel rotates/translates.  Magnitudes are in *model*
/// units (positions already downscaled).
pub fn augment_frame_jitter(e: &Example, rng: &mut Rng, max_shift: f64) -> Example {
    let z = crate::geometry::Pose::new(
        rng.range(-max_shift, max_shift),
        rng.range(-max_shift, max_shift),
        rng.range(-std::f64::consts::PI, std::f64::consts::PI),
    );
    let zi = z.inverse();
    let mut out = e.clone();
    for p in out.pose.chunks_exact_mut(3) {
        let world = crate::geometry::Pose::new(p[0] as f64, p[1] as f64, p[2] as f64);
        let shifted = zi.compose(&world);
        p[0] = shifted.x as f32;
        p[1] = shifted.y as f32;
        p[2] = shifted.theta as f32;
    }
    out
}

// --------------------------------------------------------------------------
// binary shard io
// --------------------------------------------------------------------------

fn put_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn put_f32s(w: &mut impl Write, vs: &[f32]) -> Result<()> {
    put_u32(w, vs.len() as u32)?;
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn put_i32s(w: &mut impl Write, vs: &[i32]) -> Result<()> {
    put_u32(w, vs.len() as u32)?;
    for v in vs {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn get_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn get_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = get_u32(r)? as usize;
    if n > 1 << 28 {
        bail!("corrupt shard: array too large");
    }
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn get_i32s(r: &mut impl Read) -> Result<Vec<i32>> {
    let n = get_u32(r)? as usize;
    if n > 1 << 28 {
        bail!("corrupt shard: array too large");
    }
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write examples to a shard file.
pub fn write_shard(path: impl AsRef<Path>, examples: &[Example]) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = std::io::BufWriter::new(f);
    put_u32(&mut w, MAGIC)?;
    put_u32(&mut w, VERSION)?;
    put_u32(&mut w, examples.len() as u32)?;
    for e in examples {
        put_u64(&mut w, e.scenario_seed)?;
        put_u32(&mut w, e.t0)?;
        put_u32(&mut w, e.family)?;
        put_f32s(&mut w, &e.feat)?;
        put_f32s(&mut w, &e.pose)?;
        put_i32s(&mut w, &e.tq)?;
        put_i32s(&mut w, &e.target)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a shard file.
pub fn read_shard(path: impl AsRef<Path>) -> Result<Vec<Example>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut r = std::io::BufReader::new(f);
    if get_u32(&mut r)? != MAGIC {
        bail!("not a se2attn shard (bad magic)");
    }
    let version = get_u32(&mut r)?;
    if version != VERSION {
        bail!("shard version {version}, expected {VERSION}");
    }
    let n = get_u32(&mut r)? as usize;
    if n > 1 << 24 {
        bail!("corrupt shard: implausible example count {n}");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let scenario_seed = get_u64(&mut r)?;
        let t0 = get_u32(&mut r)?;
        let family = get_u32(&mut r)?;
        out.push(Example {
            scenario_seed,
            t0,
            family,
            feat: get_f32s(&mut r)?,
            pose: get_f32s(&mut r)?,
            tq: get_i32s(&mut r)?,
            target: get_i32s(&mut r)?,
        });
    }
    Ok(out)
}

// --------------------------------------------------------------------------
// loader
// --------------------------------------------------------------------------

/// Deterministic shuffling batch iterator with train/val split.
pub struct Loader {
    pub train: Vec<Example>,
    pub val: Vec<Example>,
    batch_size: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Loader {
    pub fn new(mut examples: Vec<Example>, batch_size: usize, val_frac: f64, seed: u64) -> Loader {
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut examples);
        let n_val = ((examples.len() as f64) * val_frac) as usize;
        let val = examples.split_off(examples.len() - n_val);
        let order: Vec<usize> = (0..examples.len()).collect();
        let mut loader = Loader {
            train: examples,
            val,
            batch_size,
            order,
            cursor: 0,
            rng,
            epoch: 0,
        };
        loader.reshuffle();
        loader
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next training batch (wraps over epochs; drops ragged tail).
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch_size > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch_size];
        self.cursor += self.batch_size;
        let refs: Vec<&Example> = idx.iter().map(|&i| &self.train[i]).collect();
        collate(&refs)
    }

    /// Next batch with random SE(2) frame jitter applied to every example
    /// (the data-augmentation baseline; `max_shift` in model units).
    pub fn next_batch_augmented(&mut self, max_shift: f64) -> Batch {
        if self.cursor + self.batch_size > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let idx: Vec<usize> =
            self.order[self.cursor..self.cursor + self.batch_size].to_vec();
        self.cursor += self.batch_size;
        let augmented: Vec<Example> = idx
            .iter()
            .map(|&i| augment_frame_jitter(&self.train[i], &mut self.rng, max_shift))
            .collect();
        let refs: Vec<&Example> = augmented.iter().collect();
        collate(&refs)
    }

    /// All validation batches (fixed order).
    pub fn val_batches(&self) -> Vec<Batch> {
        self.val
            .chunks(self.batch_size)
            .filter(|c| c.len() == self.batch_size)
            .map(|c| {
                let refs: Vec<&Example> = c.iter().collect();
                collate(&refs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, SimConfig};

    fn tokenizer() -> (SimConfig, Tokenizer) {
        let sim = SimConfig::default();
        let model = ModelConfig {
            spatial_scales: vec![1.0],
            batch_size: 4,
            ..ModelConfig::synthetic()
        };
        let tok = Tokenizer::new(&model, &sim);
        (sim, tok)
    }

    #[test]
    fn generation_yields_requested_count() {
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 0, 10);
        assert_eq!(ex.len(), 10);
        // multiple windows per scenario: first two share a seed
        assert_eq!(ex[0].scenario_seed, ex[1].scenario_seed);
        assert_ne!(ex[0].t0, ex[1].t0);
    }

    #[test]
    fn shard_roundtrip() {
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 7, 6);
        let dir = std::env::temp_dir().join("se2attn_test_shard");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("x.shard");
        write_shard(&path, &ex).unwrap();
        let back = read_shard(&path).unwrap();
        assert_eq!(ex, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shard_rejects_garbage() {
        let dir = std::env::temp_dir().join("se2attn_test_shard");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bad.shard");
        std::fs::write(&path, b"this is not a shard file").unwrap();
        assert!(read_shard(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn collate_layout() {
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 1, 4);
        let refs: Vec<&Example> = ex.iter().collect();
        let b = collate(&refs);
        assert_eq!(b.batch_size, 4);
        assert_eq!(b.feat.len(), 4 * ex[0].feat.len());
        assert_eq!(&b.feat[..ex[0].feat.len()], &ex[0].feat[..]);
        assert_eq!(
            &b.tq[ex[0].tq.len()..2 * ex[0].tq.len()],
            &ex[1].tq[..]
        );
    }

    #[test]
    fn loader_split_and_epochs() {
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 2, 20);
        let mut loader = Loader::new(ex, 4, 0.2, 99);
        assert_eq!(loader.val.len(), 4);
        assert_eq!(loader.train.len(), 16);
        // 4 batches per epoch; draw 9 -> epoch advanced at least twice
        for _ in 0..9 {
            let b = loader.next_batch();
            assert_eq!(b.batch_size, 4);
        }
        assert!(loader.epoch >= 2);
        assert_eq!(loader.val_batches().len(), 1);
    }

    #[test]
    fn augmentation_preserves_invariants() {
        use crate::geometry::Pose;
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 5, 1).pop().unwrap();
        let mut rng = crate::prng::Rng::new(0);
        let aug = augment_frame_jitter(&ex, &mut rng, 2.0);
        // features, timesteps, targets untouched
        assert_eq!(ex.feat, aug.feat);
        assert_eq!(ex.tq, aug.tq);
        assert_eq!(ex.target, aug.target);
        // poses changed...
        assert_ne!(ex.pose, aug.pose);
        // ...but relative geometry between any token pair is preserved
        let pose_at = |e: &Example, i: usize| {
            Pose::new(
                e.pose[i * 3] as f64,
                e.pose[i * 3 + 1] as f64,
                e.pose[i * 3 + 2] as f64,
            )
        };
        for (i, j) in [(0usize, 5usize), (3, 20), (10, 40)] {
            let r1 = pose_at(&ex, i).relative_to(&pose_at(&ex, j));
            let r2 = pose_at(&aug, i).relative_to(&pose_at(&aug, j));
            assert!((r1.x - r2.x).abs() < 1e-4, "{r1:?} vs {r2:?}");
            assert!((r1.y - r2.y).abs() < 1e-4);
            assert!(
                crate::geometry::wrap_angle(r1.theta - r2.theta).abs() < 1e-4
            );
        }
    }

    #[test]
    fn mixed_generation_tags_families_and_roundtrips() {
        use crate::sim::suite::{FamilyId, WorkloadMix};
        let (sim, tok) = tokenizer();
        let mix =
            WorkloadMix::uniform(&[FamilyId::Roundabout, FamilyId::ParkingLot]);
        let ex = generate_examples_mix(&sim, &tok, &mix, 0, 12);
        assert_eq!(ex.len(), 12);
        let families: std::collections::BTreeSet<u32> =
            ex.iter().map(|e| e.family).collect();
        for f in &families {
            let id = FamilyId::from_index(*f as usize).unwrap();
            assert!(
                id == FamilyId::Roundabout || id == FamilyId::ParkingLot,
                "unexpected family {id:?}"
            );
        }
        // tags survive the shard format
        let dir = std::env::temp_dir().join("se2attn_test_shard_mix");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("mix.shard");
        write_shard(&path, &ex).unwrap();
        let back = read_shard(&path).unwrap();
        assert_eq!(ex, back);
        assert_eq!(back[0].family_id(), ex[0].family_id());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_generation_is_corridor_tagged() {
        use crate::sim::suite::FamilyId;
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 0, 4);
        assert!(ex.iter().all(|e| e.family_id() == FamilyId::Corridor));
    }

    #[test]
    fn loader_is_deterministic() {
        let (sim, tok) = tokenizer();
        let ex = generate_examples(&sim, &tok, 3, 12);
        let mut a = Loader::new(ex.clone(), 4, 0.0, 5);
        let mut b = Loader::new(ex, 4, 0.0, 5);
        for _ in 0..5 {
            assert_eq!(a.next_batch().tq, b.next_batch().tq);
        }
    }
}
