//! # se2attn — Linear Memory SE(2) Invariant Attention, full system
//!
//! Reproduction of "Linear Memory SE(2) Invariant Attention" (Pronovost et
//! al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas)** — flash SDPA + SE(2) Fourier projection kernels
//!   (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **L2 (JAX)** — the agent-simulation transformer
//!   (`python/compile/model.py`), four relative-attention variants.
//! * **L3 (this crate)** — the serving/training coordinator and every
//!   substrate: synthetic driving simulator with a procedural scenario
//!   suite (`sim::suite`: highway merges, signalized crossings,
//!   roundabouts, parking lots, urban crossings + a weighted workload
//!   mixer), tokenizer, dataset pipeline, PJRT runtime, the sharded
//!   serving stack (admission control + continuous step-batching
//!   scheduler, shard router, rollout engine — DESIGN.md §17) and
//!   trainer, per-class and per-family
//!   metrics, the CPU reference implementations of the paper's
//!   Algorithms 1 and 2 (backed by the blocked multithreaded flash
//!   kernel in `attention::kernel`, with the scalar path kept as the
//!   oracle), and the incremental decode engine (SE(2)-anchored KV
//!   feature cache + per-session tokenization cache, storable at a
//!   quantized f16/bf16 tier with dequant-on-attend —
//!   `attention::quant`, DESIGN.md §14) for streaming rollout, plus the
//!   observability layer (`trace` span rings + Chrome trace export,
//!   `metrics_export` Prometheus/JSON snapshots, kernel profiling —
//!   DESIGN.md §15).
//!
//! Python never runs on the request path: artifacts are compiled once by
//! `make artifacts` and loaded via the PJRT C API (`xla` crate, behind the
//! `pjrt` cargo feature; the default build ships a stub runtime so the
//! whole CPU path works offline).
//!
//! See DESIGN.md for the full system inventory and experiment index.

// The numeric kernels deliberately use indexed loops that mirror the
// paper's subscript notation (Alg. 1/2, Eq. 11-19); zipped iterators would
// obscure the correspondence that the side-by-side review relies on.
#![allow(clippy::needless_range_loop)]

pub mod attention;
pub mod benchlib;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod exec;
pub mod fourier;
pub mod geometry;
pub mod jsonio;
pub mod linalg;
pub mod metrics;
pub mod metrics_export;
pub mod obs;
pub mod prng;
pub mod proplite;
pub mod runtime;
pub mod sim;
pub mod tokenizer;
pub mod trace;
