//! Evaluation metrics (paper Table I): negative log likelihood over the
//! action codebook and minADE over sampled rollouts, broken down by
//! ground-truth trajectory class (stationary / straight / turning) and —
//! for the scenario suite — by world family (minADE + collision rate).

use std::collections::BTreeMap;

use crate::linalg::logsumexp;
use crate::sim::suite::FamilyId;
use crate::sim::TrajectoryClass;

/// Mean NLL of targets under logits.
///
/// logits: (n_tokens, n_actions) row-major; targets < 0 are skipped
/// (mirrors the model's masked loss).
pub fn nll(logits: &[f32], targets: &[i32], n_actions: usize) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (i, &t) in targets.iter().enumerate() {
        if t < 0 {
            continue;
        }
        let row = &logits[i * n_actions..(i + 1) * n_actions];
        let lz = logsumexp(row) as f64;
        total += lz - row[t as usize] as f64;
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

/// Average displacement error between a predicted and ground-truth
/// position sequence (world meters).
pub fn ade(pred: &[(f64, f64)], truth: &[(f64, f64)]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(truth.iter())
        .map(|(p, t)| ((p.0 - t.0).powi(2) + (p.1 - t.1).powi(2)).sqrt())
        .sum::<f64>()
        / pred.len() as f64
}

/// minADE over samples: each sample is one predicted trajectory.
pub fn min_ade(samples: &[Vec<(f64, f64)>], truth: &[(f64, f64)]) -> f64 {
    samples
        .iter()
        .map(|s| ade(s, truth))
        .fold(f64::INFINITY, f64::min)
}

/// Accumulates per-class minADE plus NLL — one Table-I row.
#[derive(Clone, Debug, Default)]
pub struct TableOneRow {
    nll_sum: f64,
    nll_count: usize,
    per_class: BTreeMap<&'static str, (f64, usize)>,
}

impl TableOneRow {
    pub fn add_nll(&mut self, v: f64, weight: usize) {
        self.nll_sum += v * weight as f64;
        self.nll_count += weight;
    }

    pub fn add_min_ade(&mut self, class: TrajectoryClass, v: f64) {
        let e = self.per_class.entry(class.name()).or_insert((0.0, 0));
        e.0 += v;
        e.1 += 1;
    }

    pub fn nll(&self) -> f64 {
        if self.nll_count == 0 {
            f64::NAN
        } else {
            self.nll_sum / self.nll_count as f64
        }
    }

    pub fn min_ade(&self, class: TrajectoryClass) -> f64 {
        match self.per_class.get(class.name()) {
            Some((sum, n)) if *n > 0 => sum / *n as f64,
            _ => f64::NAN,
        }
    }

    pub fn count(&self, class: TrajectoryClass) -> usize {
        self.per_class.get(class.name()).map_or(0, |(_, n)| *n)
    }
}

/// Center-to-center distance below which two agents count as colliding
/// (a vehicle-width-scale proxy; the simulator has no contact physics).
pub const COLLISION_RADIUS_M: f64 = 2.0;

/// Colliding agent pairs in one joint trajectory sample
/// (`tracks[agent][step]` = world position): a pair collides if the two
/// agents come within `radius` meters at any common step.
pub fn sample_collisions(tracks: &[Vec<(f64, f64)>], radius: f64) -> usize {
    let r2 = radius * radius;
    let mut pairs = 0;
    for i in 0..tracks.len() {
        for j in i + 1..tracks.len() {
            let steps = tracks[i].len().min(tracks[j].len());
            let hit = (0..steps).any(|t| {
                let dx = tracks[i][t].0 - tracks[j][t].0;
                let dy = tracks[i][t].1 - tracks[j][t].1;
                dx * dx + dy * dy < r2
            });
            if hit {
                pairs += 1;
            }
        }
    }
    pairs
}

#[derive(Clone, Debug, Default)]
struct FamilyAccum {
    ade_sum: f64,
    ade_n: usize,
    collisions: usize,
    samples: usize,
    rollouts: usize,
}

/// Per-family minADE / collision aggregation — the scenario-suite analogue
/// of [`TableOneRow`], keyed by [`FamilyId`].
#[derive(Clone, Debug, Default)]
pub struct FamilyBreakdown {
    per_family: BTreeMap<&'static str, FamilyAccum>,
}

impl FamilyBreakdown {
    /// Fold one rollout result in: per-agent minADEs, colliding pairs
    /// summed over the request's joint samples, and the sample count —
    /// collision rates are normalized per sample so runs with different
    /// `--samples` stay comparable.
    pub fn add_rollout(
        &mut self,
        family: FamilyId,
        min_ade: &[f64],
        collisions: usize,
        n_samples: usize,
    ) {
        let e = self.per_family.entry(family.name()).or_default();
        for &a in min_ade {
            if a.is_finite() {
                e.ade_sum += a;
                e.ade_n += 1;
            }
        }
        e.collisions += collisions;
        e.samples += n_samples;
        e.rollouts += 1;
    }

    pub fn rollouts(&self, family: FamilyId) -> usize {
        self.per_family.get(family.name()).map_or(0, |e| e.rollouts)
    }

    pub fn min_ade(&self, family: FamilyId) -> f64 {
        match self.per_family.get(family.name()) {
            Some(e) if e.ade_n > 0 => e.ade_sum / e.ade_n as f64,
            _ => f64::NAN,
        }
    }

    /// Mean colliding pairs per joint trajectory sample.
    pub fn collision_rate(&self, family: FamilyId) -> f64 {
        match self.per_family.get(family.name()) {
            Some(e) if e.samples > 0 => e.collisions as f64 / e.samples as f64,
            _ => f64::NAN,
        }
    }

    /// One line per family that saw traffic, for report tails.
    pub fn summary_lines(&self) -> Vec<String> {
        FamilyId::ALL
            .iter()
            .filter(|f| self.rollouts(**f) > 0)
            .map(|f| {
                format!(
                    "{:<22} n={:<4} minADE {:>6.2} m  collisions/sample {:.2}",
                    f.name(),
                    self.rollouts(*f),
                    self.min_ade(*f),
                    self.collision_rate(*f)
                )
            })
            .collect()
    }
}

/// Mean and sample-std over per-seed results (Table I reports means of 3
/// seeds).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_uniform_logits() {
        // all-zero logits over 4 actions -> nll = ln 4 everywhere
        let logits = vec![0.0f32; 3 * 4];
        let targets = vec![0, 3, -1];
        let v = nll(&logits, &targets, 4);
        assert!((v - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_confident_correct_is_small() {
        let mut logits = vec![0.0f32; 4];
        logits[2] = 20.0;
        assert!(nll(&logits, &[2], 4) < 1e-6);
        assert!(nll(&logits, &[1], 4) > 10.0);
    }

    #[test]
    fn nll_ignores_masked() {
        let logits = vec![1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(nll(&logits, &[-1], 4), 0.0);
    }

    #[test]
    fn ade_known_value() {
        let pred = vec![(0.0, 0.0), (1.0, 0.0)];
        let truth = vec![(0.0, 1.0), (1.0, 2.0)];
        assert!((ade(&pred, &truth) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn min_ade_takes_best_sample() {
        let truth = vec![(0.0, 0.0), (1.0, 0.0)];
        let samples = vec![
            vec![(0.0, 5.0), (1.0, 5.0)], // ade 5
            vec![(0.0, 1.0), (1.0, 1.0)], // ade 1
        ];
        assert!((min_ade(&samples, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_row_aggregates_by_class() {
        let mut row = TableOneRow::default();
        row.add_nll(2.0, 10);
        row.add_nll(4.0, 10);
        row.add_min_ade(TrajectoryClass::Turning, 2.0);
        row.add_min_ade(TrajectoryClass::Turning, 4.0);
        row.add_min_ade(TrajectoryClass::Straight, 1.0);
        assert!((row.nll() - 3.0).abs() < 1e-12);
        assert!((row.min_ade(TrajectoryClass::Turning) - 3.0).abs() < 1e-12);
        assert!((row.min_ade(TrajectoryClass::Straight) - 1.0).abs() < 1e-12);
        assert!(row.min_ade(TrajectoryClass::Stationary).is_nan());
        assert_eq!(row.count(TrajectoryClass::Turning), 2);
    }

    #[test]
    fn sample_collisions_counts_close_pairs() {
        // agents 0/1 brush past each other at step 1; agent 2 stays away
        let tracks = vec![
            vec![(0.0, 0.0), (5.0, 0.0), (10.0, 0.0)],
            vec![(10.0, 0.0), (5.5, 0.0), (0.0, 0.0)],
            vec![(0.0, 50.0), (5.0, 50.0), (10.0, 50.0)],
        ];
        assert_eq!(sample_collisions(&tracks, 2.0), 1);
        assert_eq!(sample_collisions(&tracks, 0.1), 0);
        // ragged/empty tracks are safe
        assert_eq!(sample_collisions(&[vec![], vec![(0.0, 0.0)]], 2.0), 0);
    }

    #[test]
    fn family_breakdown_aggregates() {
        let mut b = FamilyBreakdown::default();
        b.add_rollout(FamilyId::Roundabout, &[2.0, 4.0], 2, 4);
        b.add_rollout(FamilyId::Roundabout, &[6.0], 0, 4);
        b.add_rollout(FamilyId::ParkingLot, &[1.0, f64::NAN], 0, 1);
        assert_eq!(b.rollouts(FamilyId::Roundabout), 2);
        assert!((b.min_ade(FamilyId::Roundabout) - 4.0).abs() < 1e-12);
        // 2 colliding pairs over 8 joint samples: per-sample rate, so the
        // number is comparable across different --samples settings
        assert!((b.collision_rate(FamilyId::Roundabout) - 0.25).abs() < 1e-12);
        assert!((b.min_ade(FamilyId::ParkingLot) - 1.0).abs() < 1e-12, "NaN skipped");
        assert!(b.min_ade(FamilyId::HighwayMerge).is_nan());
        assert!(b.collision_rate(FamilyId::HighwayMerge).is_nan());
        assert_eq!(b.summary_lines().len(), 2);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
    }
}
