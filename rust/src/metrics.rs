//! Evaluation metrics (paper Table I): negative log likelihood over the
//! action codebook and minADE over sampled rollouts, broken down by
//! ground-truth trajectory class (stationary / straight / turning).

use std::collections::BTreeMap;

use crate::linalg::logsumexp;
use crate::sim::TrajectoryClass;

/// Mean NLL of targets under logits.
///
/// logits: (n_tokens, n_actions) row-major; targets < 0 are skipped
/// (mirrors the model's masked loss).
pub fn nll(logits: &[f32], targets: &[i32], n_actions: usize) -> f64 {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (i, &t) in targets.iter().enumerate() {
        if t < 0 {
            continue;
        }
        let row = &logits[i * n_actions..(i + 1) * n_actions];
        let lz = logsumexp(row) as f64;
        total += lz - row[t as usize] as f64;
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

/// Average displacement error between a predicted and ground-truth
/// position sequence (world meters).
pub fn ade(pred: &[(f64, f64)], truth: &[(f64, f64)]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(truth.iter())
        .map(|(p, t)| ((p.0 - t.0).powi(2) + (p.1 - t.1).powi(2)).sqrt())
        .sum::<f64>()
        / pred.len() as f64
}

/// minADE over samples: each sample is one predicted trajectory.
pub fn min_ade(samples: &[Vec<(f64, f64)>], truth: &[(f64, f64)]) -> f64 {
    samples
        .iter()
        .map(|s| ade(s, truth))
        .fold(f64::INFINITY, f64::min)
}

/// Accumulates per-class minADE plus NLL — one Table-I row.
#[derive(Clone, Debug, Default)]
pub struct TableOneRow {
    nll_sum: f64,
    nll_count: usize,
    per_class: BTreeMap<&'static str, (f64, usize)>,
}

impl TableOneRow {
    pub fn add_nll(&mut self, v: f64, weight: usize) {
        self.nll_sum += v * weight as f64;
        self.nll_count += weight;
    }

    pub fn add_min_ade(&mut self, class: TrajectoryClass, v: f64) {
        let e = self.per_class.entry(class.name()).or_insert((0.0, 0));
        e.0 += v;
        e.1 += 1;
    }

    pub fn nll(&self) -> f64 {
        if self.nll_count == 0 {
            f64::NAN
        } else {
            self.nll_sum / self.nll_count as f64
        }
    }

    pub fn min_ade(&self, class: TrajectoryClass) -> f64 {
        match self.per_class.get(class.name()) {
            Some((sum, n)) if *n > 0 => sum / *n as f64,
            _ => f64::NAN,
        }
    }

    pub fn count(&self, class: TrajectoryClass) -> usize {
        self.per_class.get(class.name()).map_or(0, |(_, n)| *n)
    }
}

/// Mean and sample-std over per-seed results (Table I reports means of 3
/// seeds).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_uniform_logits() {
        // all-zero logits over 4 actions -> nll = ln 4 everywhere
        let logits = vec![0.0f32; 3 * 4];
        let targets = vec![0, 3, -1];
        let v = nll(&logits, &targets, 4);
        assert!((v - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn nll_confident_correct_is_small() {
        let mut logits = vec![0.0f32; 4];
        logits[2] = 20.0;
        assert!(nll(&logits, &[2], 4) < 1e-6);
        assert!(nll(&logits, &[1], 4) > 10.0);
    }

    #[test]
    fn nll_ignores_masked() {
        let logits = vec![1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(nll(&logits, &[-1], 4), 0.0);
    }

    #[test]
    fn ade_known_value() {
        let pred = vec![(0.0, 0.0), (1.0, 0.0)];
        let truth = vec![(0.0, 1.0), (1.0, 2.0)];
        assert!((ade(&pred, &truth) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn min_ade_takes_best_sample() {
        let truth = vec![(0.0, 0.0), (1.0, 0.0)];
        let samples = vec![
            vec![(0.0, 5.0), (1.0, 5.0)], // ade 5
            vec![(0.0, 1.0), (1.0, 1.0)], // ade 1
        ];
        assert!((min_ade(&samples, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table_row_aggregates_by_class() {
        let mut row = TableOneRow::default();
        row.add_nll(2.0, 10);
        row.add_nll(4.0, 10);
        row.add_min_ade(TrajectoryClass::Turning, 2.0);
        row.add_min_ade(TrajectoryClass::Turning, 4.0);
        row.add_min_ade(TrajectoryClass::Straight, 1.0);
        assert!((row.nll() - 3.0).abs() < 1e-12);
        assert!((row.min_ade(TrajectoryClass::Turning) - 3.0).abs() < 1e-12);
        assert!((row.min_ade(TrajectoryClass::Straight) - 1.0).abs() < 1e-12);
        assert!(row.min_ade(TrajectoryClass::Stationary).is_nan());
        assert_eq!(row.count(TrajectoryClass::Turning), 2);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
    }
}
