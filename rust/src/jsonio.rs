//! Minimal JSON parser + writer (substrate for the absent `serde_json`).
//!
//! Supports the subset needed by the artifact manifests and benchmark
//! reports: objects, arrays, strings (with escapes), numbers, booleans and
//! null.  The parser is a straightforward recursive-descent over bytes; the
//! writer escapes strings and prints numbers losslessly enough for metric
//! rows.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors --------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Builder helper for object literals.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && self.bytes[self.pos] & 0xC0 == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// --------------------------------------------------------------------------
// writer
// --------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "name": "fwd_se2fourier",
            "inputs": [
                {"name": "param:embed_w", "shape": [16, 96], "dtype": "float32"},
                {"name": "tq", "shape": [8, 64], "dtype": "int32"}
            ],
            "outputs": [{"name": "logits", "shape": [8, 64, 64], "dtype": "float32"}]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "fwd_se2fourier");
        let inputs = j.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs.len(), 2);
        let shape: Vec<usize> = inputs[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![16, 96]);
        assert_eq!(inputs[1].get("dtype").unwrap().as_str().unwrap(), "int32");
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("s", Json::Str("he\"llo\n".into())),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\u{e9}b");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-2.5e3").unwrap().as_f64().unwrap(), -2500.0);
        assert_eq!(Json::parse("0").unwrap().as_f64().unwrap(), 0.0);
    }
}
