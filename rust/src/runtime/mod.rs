//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the request path.
//!
//! Two backends behind one API (DESIGN.md §4):
//!
//! * **`pjrt` feature on** — wraps the `xla` crate (PJRT C API):
//!   `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//!   `client.compile` -> `execute`.  Text is the interchange format
//!   (xla_extension 0.5.1 rejects jax>=0.5 serialized protos).  Enabling
//!   the feature requires adding the `xla` crate to `rust/Cargo.toml`.
//! * **default (stub)** — every artifact load fails loudly with an
//!   actionable message.  The whole CPU-path system (native attention,
//!   incremental decode cache, simulator, tokenizer, dataset, batcher,
//!   telemetry) builds and tests without the XLA toolchain installed.
//!
//! Every artifact carries a JSON manifest (input/output names, shapes,
//! dtypes) emitted by `python/compile/aot.py`; the [`Engine`] validates
//! every call against it, so shape drift between the Python and Rust sides
//! fails loudly at the boundary instead of inside XLA.

pub mod tensor;

use anyhow::{bail, Context, Result};

use crate::jsonio::Json;

pub use tensor::{Dtype, HostTensor};

/// True when this build carries the real PJRT backend.  Integration tests
/// and benches that need artifacts check this and skip otherwise.
pub const PJRT_ENABLED: bool = cfg!(feature = "pjrt");

/// One input or output slot of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<IoSpec> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("io entry missing name")?
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("io entry missing shape")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let dtype = match j.get("dtype").and_then(Json::as_str) {
            Some("float32") => Dtype::F32,
            Some("int32") => Dtype::I32,
            Some(other) => bail!("unsupported dtype {other}"),
            None => bail!("io entry missing dtype"),
        };
        Ok(IoSpec { name, shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed manifest of one artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .context("manifest missing name")?
            .to_string();
        let parse_list = |key: &str| -> Result<Vec<IoSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("manifest missing {key}"))?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        Ok(Manifest {
            name,
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
        })
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

/// Validate a call's inputs against a manifest (shared by both backends).
fn validate_inputs(m: &Manifest, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != m.inputs.len() {
        bail!(
            "{}: expected {} inputs, got {}",
            m.name,
            m.inputs.len(),
            inputs.len()
        );
    }
    for (t, spec) in inputs.iter().zip(&m.inputs) {
        if t.shape != spec.shape || t.dtype() != spec.dtype {
            bail!(
                "{}: input '{}' expects {:?}{:?}, got {:?}{:?}",
                m.name,
                spec.name,
                spec.dtype,
                spec.shape,
                t.dtype(),
                t.shape
            );
        }
    }
    Ok(())
}

/// The kernel tiling contract shared by both backends (DESIGN.md §18):
/// whatever `{block_m, lanes, threads}` the native CPU kernel resolves —
/// default, `SE2ATTN_KERNEL_*`-pinned, or picked by
/// [`crate::attention::kernel::KernelConfig::autotune`] — is also the
/// shape a PJRT-lowered fused kernel must be compiled with, so a mixed
/// deployment never runs two different tilings for one model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelTiling {
    /// Key rows per kernel block (the fused path's k~/v~ tile height).
    pub block_m: usize,
    /// FMA lane width of the score/value inner loops.
    pub lanes: usize,
    /// Worker threads partitioning query chunks.
    pub threads: usize,
}

/// Resolve the shared tiling from a kernel config, normalizing exactly
/// the way the native kernel does before launch — both backends call
/// this one function, which *is* the contract.
pub fn kernel_tiling(cfg: &crate::attention::kernel::KernelConfig) -> KernelTiling {
    let c = cfg.normalized();
    KernelTiling {
        block_m: c.block_m,
        lanes: c.lanes,
        threads: c.threads,
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    //! Real PJRT backend (requires the `xla` crate).

    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::Mutex;
    use std::time::Instant;

    use anyhow::{bail, Context, Result};

    use super::tensor::HostTensor;
    use super::{validate_inputs, Manifest};

    /// A loaded, compiled artifact.
    pub struct Artifact {
        pub manifest: Manifest,
        exe: xla::PjRtLoadedExecutable,
        /// Cumulative execution statistics (for telemetry).
        pub exec_count: std::sync::atomic::AtomicU64,
        pub exec_nanos: std::sync::atomic::AtomicU64,
    }

    impl Artifact {
        /// Execute with host tensors; returns outputs in manifest order.
        pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            let m = &self.manifest;
            validate_inputs(m, inputs)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(HostTensor::to_literal)
                .collect::<Result<_>>()?;
            let t0 = Instant::now();
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let tuple = result[0][0].to_literal_sync()?;
            self.exec_count
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.exec_nanos.fetch_add(
                t0.elapsed().as_nanos() as u64,
                std::sync::atomic::Ordering::Relaxed,
            );
            let parts = tuple.to_tuple()?;
            if parts.len() != m.outputs.len() {
                bail!(
                    "{}: expected {} outputs, got {}",
                    m.name,
                    m.outputs.len(),
                    parts.len()
                );
            }
            parts
                .into_iter()
                .zip(&m.outputs)
                .map(|(lit, spec)| HostTensor::from_literal(&lit, spec))
                .collect()
        }

        pub fn mean_exec_ms(&self) -> f64 {
            let n = self.exec_count.load(std::sync::atomic::Ordering::Relaxed);
            if n == 0 {
                return 0.0;
            }
            self.exec_nanos.load(std::sync::atomic::Ordering::Relaxed) as f64
                / n as f64
                / 1e6
        }
    }

    /// The runtime engine: one PJRT client + a lazy artifact cache.
    pub struct Engine {
        client: xla::PjRtClient,
        dir: PathBuf,
        artifacts: Mutex<HashMap<String, std::sync::Arc<Artifact>>>,
    }

    impl Engine {
        /// Create a CPU engine over an artifact directory.
        pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Engine {
                client,
                dir: artifact_dir.into(),
                artifacts: Mutex::new(HashMap::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load (or fetch cached) artifact by name.
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
            if let Some(a) = self.artifacts.lock().unwrap().get(name) {
                return Ok(std::sync::Arc::clone(a));
            }
            let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
            let man_path = self.dir.join(format!("{name}.manifest.json"));
            let manifest = Manifest::parse(
                &std::fs::read_to_string(&man_path)
                    .with_context(|| format!("read {}", man_path.display()))?,
            )?;
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            eprintln!(
                "[runtime] compiled {name} in {:.2}s ({} in / {} out)",
                t0.elapsed().as_secs_f64(),
                manifest.inputs.len(),
                manifest.outputs.len()
            );
            let artifact = std::sync::Arc::new(Artifact {
                manifest,
                exe,
                exec_count: Default::default(),
                exec_nanos: Default::default(),
            });
            self.artifacts
                .lock()
                .unwrap()
                .insert(name.to_string(), std::sync::Arc::clone(&artifact));
            Ok(artifact)
        }

        /// Convenience: load + execute.
        pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            self.load(name)?.execute(inputs)
        }

        /// Names of currently loaded artifacts.
        pub fn loaded(&self) -> Vec<String> {
            self.artifacts.lock().unwrap().keys().cloned().collect()
        }

        /// Kernel tiling this backend would lower fused attention with —
        /// by construction identical to the native CPU kernel's shape
        /// (see [`super::kernel_tiling`]).
        pub fn tiling(
            &self,
            cfg: &crate::attention::kernel::KernelConfig,
        ) -> super::KernelTiling {
            super::kernel_tiling(cfg)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend: same API, artifact execution fails loudly.  Keeps the
    //! default (offline) build of the CPU-path system compiling end to end.

    use std::path::PathBuf;

    use anyhow::{bail, Result};

    use super::tensor::HostTensor;
    use super::{validate_inputs, Manifest};

    /// Stub artifact: carries a manifest, refuses to execute.
    pub struct Artifact {
        pub manifest: Manifest,
        pub exec_count: std::sync::atomic::AtomicU64,
        pub exec_nanos: std::sync::atomic::AtomicU64,
    }

    impl Artifact {
        pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            validate_inputs(&self.manifest, inputs)?;
            bail!(
                "artifact '{}': this build has no PJRT backend — rebuild \
                 with `--features pjrt` (and the `xla` dependency)",
                self.manifest.name
            );
        }

        pub fn mean_exec_ms(&self) -> f64 {
            0.0
        }
    }

    /// Stub engine: remembers the artifact directory, fails on load.
    pub struct Engine {
        dir: PathBuf,
    }

    impl Engine {
        pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Engine> {
            Ok(Engine {
                dir: artifact_dir.into(),
            })
        }

        pub fn platform(&self) -> String {
            "cpu (stub — built without the `pjrt` feature)".to_string()
        }

        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Artifact>> {
            bail!(
                "cannot load artifact '{}' from {}: this build has no PJRT \
                 backend — rebuild with `--features pjrt` (and the `xla` \
                 dependency); the native CPU attention path does not need it",
                name,
                self.dir.display()
            );
        }

        pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            self.load(name)?.execute(inputs)
        }

        pub fn loaded(&self) -> Vec<String> {
            Vec::new()
        }

        /// Kernel tiling this backend would lower fused attention with —
        /// the stub mirrors the real backend's contract exactly (one
        /// shared [`super::kernel_tiling`] resolution), so code written
        /// against the stub observes the same shape decisions.
        pub fn tiling(
            &self,
            cfg: &crate::attention::kernel::KernelConfig,
        ) -> super::KernelTiling {
            super::kernel_tiling(cfg)
        }
    }
}

pub use backend::{Artifact, Engine};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_lookup() {
        let text = r#"{
            "name": "toy",
            "inputs": [
                {"name": "a", "shape": [2, 3], "dtype": "float32"},
                {"name": "b", "shape": [], "dtype": "int32"}
            ],
            "outputs": [{"name": "o", "shape": [2], "dtype": "float32"}]
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.inputs[0].numel(), 6);
        assert_eq!(m.inputs[1].shape.len(), 0);
        assert_eq!(m.input_index("b"), Some(1));
        assert_eq!(m.output_index("o"), Some(0));
        assert_eq!(m.output_index("nope"), None);
    }

    #[test]
    fn manifest_rejects_unknown_dtype() {
        let text = r#"{"name":"x","inputs":[{"name":"a","shape":[1],"dtype":"float64"}],"outputs":[]}"#;
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn validate_inputs_catches_shape_drift() {
        let m = Manifest::parse(
            r#"{"name":"t","inputs":[{"name":"a","shape":[2],"dtype":"float32"}],"outputs":[]}"#,
        )
        .unwrap();
        assert!(validate_inputs(&m, &[HostTensor::f32(vec![2], vec![0.0; 2])]).is_ok());
        assert!(validate_inputs(&m, &[HostTensor::f32(vec![3], vec![0.0; 3])]).is_err());
        assert!(validate_inputs(&m, &[HostTensor::i32(vec![2], vec![0; 2])]).is_err());
        assert!(validate_inputs(&m, &[]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_fails_loudly() {
        let e = Engine::cpu("artifacts").unwrap();
        assert!(e.platform().contains("stub"));
        let err = e.load("decode_se2fourier").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
        assert!(e.loaded().is_empty());
    }

    #[test]
    fn engine_tiling_matches_native_kernel_shape() {
        use crate::attention::kernel::KernelConfig;
        let e = Engine::cpu("artifacts").unwrap();
        // Degenerate values must normalize identically on both sides.
        let cfg = KernelConfig::fixed(0, 5, 0);
        let t = e.tiling(&cfg);
        let native = cfg.normalized();
        assert_eq!(t.block_m, native.block_m);
        assert_eq!(t.lanes, native.lanes);
        assert_eq!(t.threads, native.threads);
        // An autotuned config resolves through the same contract.
        let tuned = KernelConfig::autotune();
        assert_eq!(e.tiling(&tuned), kernel_tiling(&tuned));
    }
}
