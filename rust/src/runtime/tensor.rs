//! Host tensors and (with the `pjrt` feature) XLA Literal conversion.

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::IoSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// A host-side tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data");
        HostTensor {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data");
        HostTensor {
            shape,
            data: TensorData::I32(data),
        }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn zeros_like_spec(spec: &IoSpec) -> HostTensor {
        match spec.dtype {
            Dtype::F32 => HostTensor::f32(spec.shape.clone(), vec![0.0; spec.numel()]),
            Dtype::I32 => HostTensor::i32(spec.shape.clone(), vec![0; spec.numel()]),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match &self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Scalar convenience accessor.
    pub fn item_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("not a scalar (numel {})", v.len());
        }
        Ok(v[0])
    }

    /// Convert to an XLA literal.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Read back from an XLA literal, checking against the manifest spec.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostTensor> {
        let data = match spec.dtype {
            Dtype::F32 => TensorData::F32(
                lit.to_vec::<f32>()
                    .with_context(|| format!("read '{}' as f32", spec.name))?,
            ),
            Dtype::I32 => TensorData::I32(
                lit.to_vec::<i32>()
                    .with_context(|| format!("read '{}' as i32", spec.name))?,
            ),
        };
        let t = HostTensor {
            shape: spec.shape.clone(),
            data,
        };
        if t.numel()
            != match &t.data {
                TensorData::F32(v) => v.len(),
                TensorData::I32(v) => v.len(),
            }
        {
            bail!(
                "output '{}' numel mismatch: spec {:?} vs literal",
                spec.name,
                spec.shape
            );
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.shape.len(), 0);
        assert_eq!(*s.as_i32().unwrap(), [7]);
    }

    #[test]
    #[should_panic(expected = "shape/data")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn zeros_like_spec() {
        let spec = IoSpec {
            name: "x".into(),
            shape: vec![4, 5],
            dtype: Dtype::I32,
        };
        let t = HostTensor::zeros_like_spec(&spec);
        assert_eq!(t.numel(), 20);
        assert_eq!(t.dtype(), Dtype::I32);
    }
}
