//! Typed configuration for the whole system.
//!
//! The model-side values are *read from* `artifacts/index.json` (emitted by
//! the AOT pipeline) so Rust and JAX can never disagree about shapes; the
//! runtime/simulator knobs have CLI-overridable defaults.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jsonio::Json;

/// Attention method — kept in sync with `python/compile/config.py`.
/// (`Ord` so per-method tables can live in deterministic `BTreeMap`s.)
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Method {
    Abs,
    Rope2d,
    Se2Rep,
    Se2Fourier,
}

impl Method {
    pub const ALL: [Method; 4] =
        [Method::Abs, Method::Rope2d, Method::Se2Rep, Method::Se2Fourier];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Abs => "abs",
            Method::Rope2d => "rope2d",
            Method::Se2Rep => "se2rep",
            Method::Se2Fourier => "se2fourier",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "abs" => Method::Abs,
            "rope2d" => Method::Rope2d,
            "se2rep" => Method::Se2Rep,
            "se2fourier" => Method::Se2Fourier,
            _ => bail!("unknown attention method '{s}' \
                        (expected abs|rope2d|se2rep|se2fourier)"),
        })
    }

    /// Paper-style display name (Table I rows).
    pub fn display(&self) -> &'static str {
        match self {
            Method::Abs => "Absolute Positions",
            Method::Rope2d => "2D RoPE",
            Method::Se2Rep => "SE(2) Representation",
            Method::Se2Fourier => "SE(2) Fourier (ours)",
        }
    }
}

/// Storage precision of cached feature rows (the incremental decode
/// engine's projected `phi_k k` / `phi_k v` rows and the per-session
/// tokenization cache's agent-step rows).
///
/// `F32` stores rows verbatim (bit-exact cache round-trips).  `F16` and
/// `Bf16` store rows as 16-bit codes with a per-row scale/offset
/// (block floating point), halving the dominant resident-bytes term so
/// the same `KvCachePool` byte budget holds roughly twice the sessions
/// (DESIGN.md §14).  Quantized rows are dequantized on the fly inside
/// the blocked flash kernel's key-block loop; poses and timestamps are
/// **never** quantized, so SE(2) re-anchoring stays exact in the frame
/// even when the stored features are compressed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CachePrecision {
    /// 4 bytes/value, exact (the seed behavior and the default).
    #[default]
    F32,
    /// IEEE binary16 codes (10 mantissa bits): ~2^-11 relative rounding
    /// after per-row normalization.
    F16,
    /// bfloat16 codes (7 mantissa bits): ~2^-8 relative rounding after
    /// per-row normalization; same bytes as `F16`, wider exponent (moot
    /// here — rows are normalized before encoding).
    Bf16,
}

impl CachePrecision {
    pub const ALL: [CachePrecision; 3] =
        [CachePrecision::F32, CachePrecision::F16, CachePrecision::Bf16];

    pub fn name(&self) -> &'static str {
        match self {
            CachePrecision::F32 => "f32",
            CachePrecision::F16 => "f16",
            CachePrecision::Bf16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Result<CachePrecision> {
        Ok(match s {
            "f32" => CachePrecision::F32,
            "f16" => CachePrecision::F16,
            "bf16" => CachePrecision::Bf16,
            _ => bail!("unknown cache precision '{s}' (expected f32|f16|bf16)"),
        })
    }

    /// Bytes of one stored feature value.
    pub fn bytes_per_value(&self) -> usize {
        match self {
            CachePrecision::F32 => 4,
            CachePrecision::F16 | CachePrecision::Bf16 => 2,
        }
    }

    /// Whether rows of this precision carry a per-row scale/offset pair
    /// and need dequantization on read.
    pub fn is_quantized(&self) -> bool {
        !matches!(self, CachePrecision::F32)
    }

    /// Worst-case |decode(encode(y)) - y| for a normalized value
    /// |y| <= 1 (half-ulp at 1 under round-to-nearest-even).  The
    /// absolute row error bound is this times the row's quantization
    /// scale.
    pub fn unit_rounding(&self) -> f64 {
        match self {
            CachePrecision::F32 => 0.0,
            CachePrecision::F16 => 1.0 / 2048.0, // 2^-11
            CachePrecision::Bf16 => 1.0 / 256.0, // 2^-8
        }
    }
}

/// Model configuration baked into the artifacts (mirror of the Python
/// `ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_tokens: usize,
    pub feat_dim: usize,
    pub n_actions: usize,
    pub fourier_f: usize,
    pub spatial_scales: Vec<f64>,
    pub batch_size: usize,
    pub learning_rate: f64,
    pub map_timestep: i32,
    pub param_names: Vec<String>,
    /// Blocked flash-kernel shape for every *native* (CPU) attention this
    /// model performs — Algorithm 2 (fused and project-then-attend), the
    /// quadratic oracle's row partition and the incremental decode
    /// engine.  Not read from `index.json` (it is a host-execution knob,
    /// not a model-shape one): defaults to
    /// [`crate::attention::kernel::KernelConfig::default`] and is
    /// overridden by `ServeConfig`/CLI on the serving path — including
    /// `ServeConfig.autotune_kernel` / `simulate --kernel-autotune`,
    /// which replaces it with the
    /// [`crate::attention::kernel::KernelConfig::autotune`] pick at
    /// startup.  Whatever lands here is the one kernel shape *both*
    /// backends honor (see [`crate::runtime::kernel_tiling`]).
    pub kernel: crate::attention::kernel::KernelConfig,
    /// Storage precision of cached feature rows for engines derived from
    /// this model config
    /// ([`crate::attention::incremental::IncrementalConfig::for_model`]).
    /// Like `kernel`, a host-execution knob, not a model-shape one: not
    /// read from `index.json`, defaults to [`CachePrecision::F32`], and
    /// overridden by `ServeConfig`/CLI (`simulate --cache-precision`) on
    /// the serving path.
    pub cache_precision: CachePrecision,
}

impl ModelConfig {
    /// Parse from the `index.json` the AOT pipeline writes.
    pub fn from_index(index: &Json) -> Result<ModelConfig> {
        let c = index.get("config").context("index.json missing 'config'")?;
        let num = |k: &str| -> Result<f64> {
            c.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("config.{k} missing"))
        };
        let scales = c
            .get("spatial_scales")
            .and_then(Json::as_arr)
            .context("config.spatial_scales missing")?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let param_names = index
            .get("param_names")
            .and_then(Json::as_arr)
            .context("index.json missing param_names")?
            .iter()
            .filter_map(|j| j.as_str().map(str::to_string))
            .collect();
        Ok(ModelConfig {
            n_layers: num("n_layers")? as usize,
            n_heads: num("n_heads")? as usize,
            head_dim: num("head_dim")? as usize,
            d_model: num("d_model")? as usize,
            d_ff: num("d_ff")? as usize,
            n_tokens: num("n_tokens")? as usize,
            feat_dim: num("feat_dim")? as usize,
            n_actions: num("n_actions")? as usize,
            fourier_f: num("fourier_f")? as usize,
            spatial_scales: scales,
            batch_size: num("batch_size")? as usize,
            learning_rate: num("learning_rate")?,
            map_timestep: num("map_timestep")? as i32,
            param_names,
            kernel: crate::attention::kernel::KernelConfig::default(),
            cache_precision: CachePrecision::F32,
        })
    }

    /// Per-head projected width c for SE(2) Fourier (Sec. III-C).
    pub fn se2f_proj_dim(&self) -> usize {
        (4 * self.fourier_f + 2) * (self.head_dim / 6)
    }

    /// The artifact-free model shape used by tests, benches and doc
    /// examples: the paper's d=48, F=12 head on the default
    /// [`SimConfig`] token budget (64 tokens).  Matches what `make
    /// artifacts` would bake, with no `index.json` required.
    pub fn synthetic() -> ModelConfig {
        ModelConfig {
            n_layers: 2,
            n_heads: 2,
            head_dim: 48,
            d_model: 96,
            d_ff: 192,
            n_tokens: 64,
            feat_dim: 16,
            n_actions: 64,
            fourier_f: 12,
            spatial_scales: vec![1.0, 0.5, 0.25, 0.125],
            batch_size: 8,
            learning_rate: 3e-4,
            map_timestep: -1,
            param_names: vec![],
            kernel: crate::attention::kernel::KernelConfig::default(),
            cache_precision: CachePrecision::F32,
        }
    }
}

/// Simulator / scenario-generation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Simulation timestep in seconds (paper evaluates 6 s futures).
    pub dt: f64,
    /// History steps tokenized as context.
    pub history_steps: usize,
    /// Future steps rolled out for minADE (6 s / dt).
    pub future_steps: usize,
    /// Agents per scenario.
    pub n_agents: usize,
    /// Map tokens per scenario.
    pub n_map_tokens: usize,
    /// World-to-model position downscale: paper downscales positions to
    /// magnitude <= 4.
    pub pos_scale: f64,
    /// minADE sample count (paper: 16 joint trajectory samples).
    pub n_rollout_samples: usize,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            dt: 0.5,
            history_steps: 8,
            future_steps: 12,
            n_agents: 6,
            n_map_tokens: 16,
            pos_scale: 0.05, // +-80 m world -> +-4 model units
            n_rollout_samples: 16,
        }
    }
}

impl SimConfig {
    /// Tokens per scene = map tokens + agents x history.
    pub fn tokens_per_scene(&self) -> usize {
        self.n_map_tokens + self.n_agents * self.history_steps
    }
}

/// Resolve the CLI's `--family` / `--mix` options into a workload mix:
/// a non-empty `--mix` spec wins; `--family mixed` is a uniform mix over
/// every registered family; otherwise the single named family.
pub fn scenario_mix(family: &str, mix: &str) -> Result<crate::sim::suite::WorkloadMix> {
    use crate::sim::suite::{registry, FamilyId, WorkloadMix};
    if !mix.trim().is_empty() {
        return WorkloadMix::parse(mix);
    }
    if family == "mixed" {
        let ids: Vec<FamilyId> = registry().iter().map(|f| f.id).collect();
        return Ok(WorkloadMix::uniform(&ids));
    }
    Ok(WorkloadMix::single(FamilyId::parse(family)?))
}

/// Default worker-shard count for the serving pool: one per available
/// core, clamped to [1, 8] — beyond that the per-shard model replicas
/// cost more memory than the extra threads buy on this workload.  CLI
/// `--workers` / `ServeConfig.workers` override it.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 8)
}

/// Live introspection server settings (`simulate --obs-addr`;
/// [`crate::obs::http`], DESIGN.md §16).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Bind address, e.g. `127.0.0.1:9464` (port 0 picks an ephemeral
    /// port — the server reports the bound address).
    pub addr: String,
    /// Polling interval of the background watermark sampler feeding
    /// `/vars`.
    pub sample_interval: std::time::Duration,
    /// Sample-ring capacity: `/vars?watch=N` serves at most this many
    /// trailing samples (600 × 100 ms ≈ one minute of history).
    pub history: usize,
}

impl ObsConfig {
    /// Config for a bind address with default sampler cadence.
    pub fn at(addr: &str) -> ObsConfig {
        ObsConfig {
            addr: addr.to_string(),
            sample_interval: std::time::Duration::from_millis(100),
            history: 600,
        }
    }
}

/// Multi-process fleet settings (`simulate --worker-procs N`;
/// [`crate::coordinator::proc`], DESIGN.md §19): worker-shard child
/// processes speaking the wire protocol over a local socket, with
/// heartbeat liveness, session migration and respawn-on-death.
#[derive(Clone, Debug)]
pub struct ProcConfig {
    /// Worker heartbeat-beacon interval.  The coordinator's supervisor
    /// sweeps at half this period.
    pub heartbeat: std::time::Duration,
    /// Silence window after which a connected worker is declared dead
    /// (its requests replay elsewhere; see `death_after >= 2*heartbeat`
    /// or a jittered beacon gets declared dead spuriously).
    pub death_after: std::time::Duration,
    /// Read deadline for the `Hello` handshake on a fresh connection —
    /// bounds how long a garbage/stalled peer can hold a handshake slot.
    pub connect_timeout: std::time::Duration,
    /// Respawn workers that die (SIGKILL, crash, heartbeat timeout).
    /// Off, a dead worker stays dead and its traffic reroutes for good.
    pub respawn: bool,
    /// Do not spawn child processes at startup (and never respawn):
    /// the test harness connects worker processes itself, possibly
    /// through a fault-injection proxy.
    pub manual_workers: bool,
}

impl Default for ProcConfig {
    fn default() -> ProcConfig {
        ProcConfig {
            heartbeat: std::time::Duration::from_millis(250),
            death_after: std::time::Duration::from_secs(2),
            connect_timeout: std::time::Duration::from_secs(10),
            respawn: true,
            manual_workers: false,
        }
    }
}

/// Whole-system configuration.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub artifact_dir: PathBuf,
    pub model: ModelConfig,
    pub sim: SimConfig,
    pub threads: usize,
}

impl SystemConfig {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<SystemConfig> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let index_path = dir.join("index.json");
        let text = std::fs::read_to_string(&index_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                index_path.display()
            )
        })?;
        let index = Json::parse(&text).context("bad index.json")?;
        let model = ModelConfig::from_index(&index)?;
        let sim = SimConfig::default();
        // the tokenizer layout must agree with the model's token budget
        if sim.tokens_per_scene() != model.n_tokens {
            bail!(
                "sim layout produces {} tokens but artifacts expect {}",
                sim.tokens_per_scene(),
                model.n_tokens
            );
        }
        Ok(SystemConfig {
            artifact_dir: dir,
            model,
            sim,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_precision_roundtrip_and_bytes() {
        for p in CachePrecision::ALL {
            assert_eq!(CachePrecision::parse(p.name()).unwrap(), p);
        }
        assert!(CachePrecision::parse("f64").is_err());
        assert_eq!(CachePrecision::F32.bytes_per_value(), 4);
        assert_eq!(CachePrecision::F16.bytes_per_value(), 2);
        assert_eq!(CachePrecision::Bf16.bytes_per_value(), 2);
        assert!(!CachePrecision::F32.is_quantized());
        assert!(CachePrecision::F16.is_quantized());
        assert_eq!(CachePrecision::default(), CachePrecision::F32);
        assert!(CachePrecision::F16.unit_rounding() < CachePrecision::Bf16.unit_rounding());
    }

    #[test]
    fn synthetic_model_config_matches_sim_budget() {
        let m = ModelConfig::synthetic();
        assert_eq!(m.n_tokens, SimConfig::default().tokens_per_scene());
        assert_eq!(m.se2f_proj_dim(), 50 * 8);
        assert_eq!(m.cache_precision, CachePrecision::F32);
    }

    #[test]
    fn method_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn model_config_from_index_json() {
        let text = r#"{
            "config": {"n_layers": 2, "n_heads": 2, "head_dim": 48,
                       "d_model": 96, "d_ff": 192, "n_tokens": 64,
                       "feat_dim": 16, "n_actions": 64, "fourier_f": 12,
                       "spatial_scales": [1.0, 0.5, 0.25, 2.0],
                       "batch_size": 8, "learning_rate": 0.0003,
                       "map_timestep": -1},
            "param_names": ["embed_b", "embed_w"],
            "artifacts": []
        }"#;
        let idx = Json::parse(text).unwrap();
        let mc = ModelConfig::from_index(&idx).unwrap();
        assert_eq!(mc.head_dim, 48);
        assert_eq!(mc.se2f_proj_dim(), 50 * 8);
        assert_eq!(mc.spatial_scales, vec![1.0, 0.5, 0.25, 2.0]);
        assert_eq!(mc.param_names.len(), 2);
    }

    #[test]
    fn scenario_mix_resolution() {
        use crate::sim::suite::FamilyId;
        // --mix wins over --family
        let m = scenario_mix("corridor", "roundabout:2,parking-lot:1").unwrap();
        assert_eq!(m.entries().len(), 2);
        // single family
        let s = scenario_mix("highway-merge", "").unwrap();
        assert_eq!(s.entries(), &[(FamilyId::HighwayMerge, 1.0)][..]);
        // 'mixed' covers the whole registry
        let all = scenario_mix("mixed", "").unwrap();
        assert_eq!(all.entries().len(), FamilyId::ALL.len());
        assert!(scenario_mix("bogus", "").is_err());
    }

    #[test]
    fn sim_token_budget_matches_default_model() {
        let sim = SimConfig::default();
        assert_eq!(sim.tokens_per_scene(), 64);
    }

    #[test]
    fn default_workers_is_bounded() {
        let w = default_workers();
        assert!((1..=8).contains(&w), "got {w}");
    }
}
