//! Sharded-serving tests that run WITHOUT compiled PJRT artifacts: the
//! worker pool is started on a deterministic [`SyntheticDecoder`] backend,
//! so the full serving stack — shard router, per-shard admission queues,
//! the continuous-batching step loop, per-shard KV-cache pools over the
//! shared map registry, the rollout scheduler, graceful drain — is
//! exercised in the default (stub-runtime) build on every `cargo test`.
//!
//! The headline check is **cross-shard equivalence**: the same
//! mixed-family workload through 1 worker and through 4 workers must
//! produce identical per-request `RolloutResult`s, with zero KV-pool
//! session migrations (a migrated session would re-miss on its new shard
//! and show up in the cache counters).

use std::sync::Arc;

use se2attn::config::{Method, ModelConfig, SimConfig, SystemConfig};

mod common;
use se2attn::coordinator::{
    AdmissionConfig, Backend, BackendFactory, CacheConfig, RolloutRequest, RolloutResult, Router,
    ServeConfig, Server, SyntheticDecoder,
};
use se2attn::sim::{MixGenerator, Scenario, ScenarioGenerator};

const METHOD: Method = Method::Se2Fourier;

fn test_model_config() -> ModelConfig {
    ModelConfig::synthetic()
}

fn test_system_config() -> SystemConfig {
    SystemConfig {
        artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
        model: test_model_config(),
        sim: SimConfig::default(),
        threads: 1,
    }
}

/// Factory deploying one synthetic replica of `METHOD` per shard.
fn synthetic_factory() -> BackendFactory {
    Arc::new(|_shard: usize| -> anyhow::Result<Backend> {
        let mut backend: Backend = Router::new();
        let decoder = SyntheticDecoder::new(test_model_config().n_actions);
        backend.deploy(METHOD, Box::new(decoder));
        Ok(backend)
    })
}

fn synthetic_server(workers: usize, admission: AdmissionConfig) -> Server {
    Server::start_with_backend(
        test_system_config(),
        vec![METHOD],
        ServeConfig {
            workers,
            admission,
            cache: CacheConfig::default(),
            kernel: se2attn::attention::kernel::KernelConfig::default(),
            ..ServeConfig::default()
        },
        synthetic_factory(),
    )
    .expect("synthetic server start")
}

/// An admission config whose pacing can never fire (sub-unit burst): the
/// queue fills deterministically and only the shutdown drain serves it.
/// The continuous-scheduler replacement for the old never-flush batcher.
fn never_admit(max_queue: usize) -> AdmissionConfig {
    AdmissionConfig {
        max_queue,
        tenant_rate: 1e-9,
        tenant_burst: 0.0,
        ..AdmissionConfig::default()
    }
}

fn request_for(scenario: Scenario, i: usize, n_samples: usize) -> RolloutRequest {
    let sim = SimConfig::default();
    RolloutRequest {
        scenario,
        t0: sim.history_steps - 1,
        n_samples,
        temperature: 1.0,
        seed: i as i32,
    }
}

/// Run the same mixed-family workload through a server and return the
/// per-request results in submission order.
fn run_workload(server: &Server, scenes: usize, n_samples: usize) -> Vec<RolloutResult> {
    let sim = SimConfig::default();
    let mix = se2attn::config::scenario_mix("mixed", "").unwrap();
    let gen = MixGenerator::new(sim, mix);
    let mut pending = Vec::new();
    for i in 0..scenes {
        let scenario = gen.generate(1000 + i as u64);
        pending.push(server.submit(METHOD, request_for(scenario, i, n_samples)));
    }
    pending
        .into_iter()
        .map(|rx| rx.recv().expect("shard alive").expect("rollout ok"))
        .collect()
}

/// Acceptance gate: identical per-request results through 1 vs 4 workers,
/// zero session migrations, deterministic shard pinning.
#[test]
fn cross_shard_equivalence_on_mixed_workload() {
    let scenes = 24;
    let samples = 2;
    let sim = SimConfig::default();
    // a small live-session cap keeps several requests sharing each step
    // batch, so heterogeneous (per-slot seeded) packing is exercised —
    // equivalence holds because step seeds are a pure function of
    // (request, step, sample), never of how the batch was packed
    let admission = AdmissionConfig {
        max_queue: 1024,
        max_live_sessions: 4,
        ..AdmissionConfig::default()
    };

    let server1 = synthetic_server(1, admission.clone());
    let results1 = run_workload(&server1, scenes, samples);
    let stats1 = Arc::clone(&server1.stats);
    drop(server1);

    let server4 = synthetic_server(4, admission);
    // shard pinning is a pure function of the scene id: record the
    // expected per-shard request counts before submitting
    let mix = se2attn::config::scenario_mix("mixed", "").unwrap();
    let gen = MixGenerator::new(sim.clone(), mix);
    let mut expected_per_shard = [0u64; 4];
    for i in 0..scenes {
        expected_per_shard[server4.shard_for(&gen.generate(1000 + i as u64))] += 1;
    }
    let results4 = run_workload(&server4, scenes, samples);
    let stats4 = Arc::clone(&server4.stats);

    // identical per-request results (decode_ms is wall-clock, excluded)
    assert_eq!(results1.len(), results4.len());
    for (i, (a, b)) in results1.iter().zip(results4.iter()).enumerate() {
        assert_eq!(a.trajectories, b.trajectories, "request {i}: trajectories");
        assert_eq!(a.min_ade, b.min_ade, "request {i}: minADE");
        assert_eq!(a.classes, b.classes, "request {i}: classes");
        assert_eq!(a.collisions, b.collisions, "request {i}: collisions");
    }

    // the workload actually spread over shards, exactly as the affinity
    // hash predicts
    for (i, s) in stats4.shards.iter().enumerate() {
        assert_eq!(
            s.requests.get(),
            expected_per_shard[i],
            "shard {i} request count"
        );
    }
    assert!(
        expected_per_shard.iter().filter(|&&c| c > 0).count() >= 2,
        "mixed workload must hit at least two shards: {expected_per_shard:?}"
    );

    // zero session migrations: every (request, sample) session misses
    // exactly once (its first decode step) and hits on every later step —
    // a migrated session would re-miss on its new shard's pool
    let n_sessions = (scenes * samples) as u64;
    let hits_per_session = (sim.future_steps - 1) as u64;
    for (label, stats) in [("1 worker", &stats1), ("4 workers", &stats4)] {
        assert_eq!(stats.requests_done.get(), scenes as u64, "{label}: done");
        assert_eq!(stats.requests_failed.get(), 0, "{label}: failed");
        assert_eq!(stats.cache.misses.get(), n_sessions, "{label}: misses");
        assert_eq!(
            stats.cache.hits.get(),
            n_sessions * hits_per_session,
            "{label}: hits"
        );
        assert_eq!(stats.cache.evictions.get(), 0, "{label}: evictions");
        // shared map registry: one tokenization per scene server-wide,
        // regardless of which shard first touched the scene
        assert_eq!(stats.cache.map_misses.get(), scenes as u64, "{label}: map misses");
    }
}

/// A malformed request (zero rollout samples) must come back as a
/// per-request error — the shard worker keeps serving, its inflight
/// gauge settles, and the next request on the same shard succeeds.
#[test]
fn zero_sample_request_is_a_recoverable_error() {
    let server = synthetic_server(
        common::test_workers(2),
        AdmissionConfig {
            max_queue: 16,
            ..AdmissionConfig::default()
        },
    );
    let gen = ScenarioGenerator::new(SimConfig::default());
    let scenario = gen.generate(11);
    let err = server
        .call(METHOD, request_for(scenario.clone(), 0, 0))
        .expect_err("zero samples must error, not panic the shard");
    assert!(format!("{err:#}").contains("zero samples"), "{err:#}");
    assert_eq!(server.stats.requests_failed.get(), 1);
    // the same shard (same scene -> same pin) still serves real traffic
    let res = server
        .call(METHOD, request_for(scenario, 1, 1))
        .expect("shard must survive the bad request");
    assert_eq!(res.trajectories.len(), 1);
    for s in &server.stats.shards {
        assert_eq!(s.inflight.get(), 0);
    }
}

/// Regression (ISSUE 3 satellite): a submit after shutdown used to
/// silently swallow the send but still count `requests_in`; it must now
/// answer with an explicit error and leave the counters untouched.
#[test]
fn submit_after_shutdown_errors_and_is_not_counted() {
    // default to 2 shards so the synthetic suite covers multi-shard
    // shutdown even without the CI env override
    let workers = common::test_workers(2);
    let mut server = synthetic_server(
        workers,
        AdmissionConfig {
            max_queue: 16,
            ..AdmissionConfig::default()
        },
    );
    let gen = ScenarioGenerator::new(SimConfig::default());
    let res = server
        .call(METHOD, request_for(gen.generate(7), 0, 1))
        .expect("live server must serve");
    assert_eq!(res.min_ade.len(), SimConfig::default().n_agents);
    assert_eq!(server.stats.requests_in.get(), 1);

    server.shutdown();

    let rx = server.submit(METHOD, request_for(gen.generate(8), 1, 1));
    let err = rx
        .recv()
        .expect("rejection must arrive as an explicit message, not a hangup")
        .expect_err("a shut-down server must not serve");
    assert!(format!("{err:#}").contains("shut down"), "{err:#}");
    assert_eq!(
        server.stats.requests_in.get(),
        1,
        "a rejected submit must not count as accepted"
    );
    assert_eq!(server.stats.requests_done.get(), 1);

    // shutdown is idempotent
    server.shutdown();
}

/// Per-shard backpressure: a hot shard fills its own queue and rejects
/// its own overflow, while a sibling shard keeps accepting — one hot
/// scene family cannot starve the others.
#[test]
fn per_shard_backpressure_isolates_the_hot_shard() {
    // pacing that can never admit: requests sit queued until the
    // shutdown drain, so queue occupancy is fully deterministic
    let server = synthetic_server(2, never_admit(4));
    let gen = ScenarioGenerator::new(SimConfig::default());

    // find scenarios pinned to shard 0 (hot) and shard 1 (cold)
    let mut hot = Vec::new();
    let mut cold = Vec::new();
    let mut seed = 0u64;
    while hot.len() < 5 || cold.is_empty() {
        let s = gen.generate(seed);
        match server.shard_for(&s) {
            0 if hot.len() < 5 => hot.push(s),
            1 if cold.is_empty() => cold.push(s),
            _ => {}
        }
        seed += 1;
    }

    // 4 fill shard 0's queue; the 5th must bounce with a Busy error
    let hot_rxs: Vec<_> = hot
        .into_iter()
        .enumerate()
        .map(|(i, s)| server.submit(METHOD, request_for(s, i, 1)))
        .collect();
    // the cold shard still accepts
    let cold_rx = server.submit(METHOD, request_for(cold.pop().unwrap(), 9, 1));

    let stats = Arc::clone(&server.stats);
    drop(server); // shutdown: queued requests drain through the rollout engine

    let outcomes: Vec<Result<RolloutResult, anyhow::Error>> = hot_rxs
        .into_iter()
        .map(|rx| rx.recv().expect("answered"))
        .collect();
    for (i, o) in outcomes[..4].iter().enumerate() {
        assert!(o.is_ok(), "queued hot request {i} must drain to a result");
    }
    let busy = outcomes[4].as_ref().expect_err("overflow must bounce");
    assert!(format!("{busy:#}").contains("busy"), "{busy:#}");
    assert!(
        cold_rx.recv().expect("answered").is_ok(),
        "the cold shard must be unaffected by the hot shard's backpressure"
    );

    assert_eq!(stats.shards[0].rejected.get(), 1);
    assert_eq!(stats.shards[1].rejected.get(), 0);
    assert_eq!(stats.queue_rejections.get(), 1);
    assert_eq!(stats.requests_done.get(), 5, "4 hot drained + 1 cold");
    assert_eq!(stats.requests_failed.get(), 0);
    for s in &stats.shards {
        assert_eq!(s.inflight.get(), 0, "drain must settle inflight to zero");
    }
}

/// Regression (ISSUE 7 satellite): the Busy/queue-rejection path must
/// decrement `inflight` exactly once per bounced envelope, observable
/// **while the server is still serving** — the gauge is the
/// least-loaded routing signal and the `/vars` sampler input, so a
/// rejection that left it stuck high would skew both for the rest of
/// the process lifetime, not just until shutdown.
#[test]
fn rejected_envelopes_settle_inflight_while_serving() {
    // pacing that can never admit: occupancy and overflow are fully
    // deterministic
    let server = synthetic_server(1, never_admit(2));
    let gen = ScenarioGenerator::new(SimConfig::default());
    let scenario = gen.generate(5);
    // 6 submits onto the single shard: the first 2 queue, the last 4
    // bounce Busy (the channel and the worker both preserve order)
    let mut rxs = (0..6)
        .map(|i| server.submit(METHOD, request_for(scenario.clone(), i, 1)))
        .collect::<Vec<_>>()
        .into_iter();
    let queued: Vec<_> = rxs.by_ref().take(2).collect();
    for (i, rx) in rxs.enumerate() {
        let err = rx
            .recv()
            .expect("bounce must be answered, not dropped")
            .expect_err("overflow past max_queue must be Busy");
        assert!(format!("{err:#}").contains("busy"), "overflow {i}: {err:#}");
    }
    // the worker decrements BEFORE sending each Busy answer, so with all
    // 4 answers in hand the gauge must read exactly the queued count
    let shard = &server.stats.shards[0];
    assert_eq!(shard.inflight.get(), 2, "inflight must settle to the queued count");
    assert_eq!(shard.rejected.get(), 4);
    assert_eq!(server.stats.queue_rejections.get(), 4);
    // the saturation gauge follows one worker-loop beat behind the
    // rejection answers
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while shard.queue_depth.get() != 2 {
        assert!(
            std::time::Instant::now() < deadline,
            "queue_depth stuck at {} (want 2)",
            shard.queue_depth.get()
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(shard.live.get(), 1, "the worker must survive a rejection storm");

    let stats = Arc::clone(&server.stats);
    drop(server); // shutdown drain answers the 2 queued requests
    for rx in queued {
        rx.recv()
            .expect("answered")
            .expect("queued requests drain to real results");
    }
    assert_eq!(stats.requests_done.get(), 2);
    assert_eq!(stats.shards[0].inflight.get(), 0, "drain settles inflight to zero");
    assert_eq!(stats.shards[0].queue_depth.get(), 0, "LiveGuard clears the gauge");
    assert_eq!(stats.shards[0].live.get(), 0, "worker exit clears liveness");
}

/// Stateless submits ignore scene affinity and spread by inflight depth:
/// with no completions (admission pacing frozen), 8 submits round-robin
/// 2 onto each of 4 shards deterministically.
#[test]
fn stateless_requests_balance_across_shards() {
    let server = synthetic_server(4, never_admit(64));
    let gen = ScenarioGenerator::new(SimConfig::default());
    // all 8 requests share one scene: affinity would pile them onto a
    // single shard, least-loaded must spread them 2-2-2-2
    let scenario = gen.generate(42);
    let rxs: Vec<_> = (0..8)
        .map(|i| server.submit_stateless(METHOD, request_for(scenario.clone(), i, 1)))
        .collect();
    for (i, s) in server.stats.shards.iter().enumerate() {
        assert_eq!(s.requests.get(), 2, "shard {i} load");
    }
    let stats = Arc::clone(&server.stats);
    drop(server);
    for rx in rxs {
        rx.recv().expect("answered").expect("drained to a real result");
    }
    assert_eq!(stats.requests_done.get(), 8);
}

/// Satellite (ISSUE 8): shutdown with sessions mid-flight in a
/// continuous step batch.  A small live-session cap keeps most requests
/// waiting in the admission queue while earlier ones are being stepped,
/// so the Shutdown lands mid-step-loop; every accepted request must
/// still drain to a real result (no lost responses) and shutdown stays
/// idempotent.  Extends the PR 3 drain regression to the continuous
/// scheduler.
#[test]
fn shutdown_drains_sessions_mid_flight_in_step_batch() {
    let mut server = synthetic_server(
        1,
        AdmissionConfig {
            max_live_sessions: 2,
            ..AdmissionConfig::default()
        },
    );
    let gen = ScenarioGenerator::new(SimConfig::default());
    let rxs: Vec<_> = (0..8)
        .map(|i| server.submit(METHOD, request_for(gen.generate(100 + i as u64), i, 2)))
        .collect();
    // shutdown races the step loop: whatever is live keeps stepping to
    // retirement, whatever is queued drains unpaced through the loop
    server.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let res = rx
            .recv()
            .expect("no lost responses across shutdown")
            .unwrap_or_else(|e| panic!("request {i} must drain to a result: {e:#}"));
        assert_eq!(res.trajectories.len(), 2, "request {i}");
    }
    assert_eq!(server.stats.requests_done.get(), 8);
    assert_eq!(server.stats.requests_failed.get(), 0);
    assert_eq!(server.stats.queue_sheds.get(), 0, "drain must never shed");
    // idempotent: a second shutdown is a no-op
    server.shutdown();
    for s in &server.stats.shards {
        assert_eq!(s.inflight.get(), 0);
        assert_eq!(s.live_sessions.get(), 0, "WorkerGuard clears occupancy");
    }
}

/// Satellite (ISSUE 8): session-affinity routing is a pure function of
/// the scene id — repeated submits of the same scene always land on the
/// pinned shard (never migrating once admitted), and the pin is stable
/// across server instances with the same shard count.
#[test]
fn session_affinity_never_migrates_once_admitted() {
    let server = synthetic_server(4, AdmissionConfig::default());
    let gen = ScenarioGenerator::new(SimConfig::default());
    let scenarios: Vec<Scenario> = (0..16).map(|s| gen.generate(s)).collect();
    let pins: Vec<usize> = scenarios.iter().map(|s| server.shard_for(s)).collect();
    let mut rxs = Vec::new();
    for (i, sc) in scenarios.iter().enumerate() {
        for r in 0..3 {
            rxs.push(server.submit(METHOD, request_for(sc.clone(), i * 3 + r, 1)));
        }
    }
    for rx in rxs {
        rx.recv().expect("answered").expect("rollout ok");
    }
    // per-shard request counters match the pure pin prediction exactly:
    // no submit was routed (or re-routed mid-flight) anywhere else
    let mut expected = [0u64; 4];
    for &p in &pins {
        expected[p] += 3;
    }
    for (i, s) in server.stats.shards.iter().enumerate() {
        assert_eq!(s.requests.get(), expected[i], "shard {i} request count");
    }
    // the pin survives a server restart (same shard count)
    let server2 = synthetic_server(4, AdmissionConfig::default());
    for (s, &p) in scenarios.iter().zip(&pins) {
        assert_eq!(server2.shard_for(s), p, "pin must be instance-independent");
    }
}

/// Satellite (ISSUE 8): least-inflight tie-breaking is deterministic —
/// frozen admission pacing makes the inflight gauges advance in
/// lockstep with the submits, so two identical stateless submit
/// sequences must produce identical shard assignments, filling shards
/// in index order on exact ties.
#[test]
fn stateless_tie_break_is_deterministic_under_equal_load() {
    let run = || {
        let server = synthetic_server(3, never_admit(64));
        let gen = ScenarioGenerator::new(SimConfig::default());
        let scenario = gen.generate(42);
        let mut per_submit = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit_stateless(METHOD, request_for(scenario.clone(), i, 1)));
            per_submit.push(
                server
                    .stats
                    .shards
                    .iter()
                    .map(|s| s.requests.get())
                    .collect::<Vec<u64>>(),
            );
        }
        drop(server);
        for rx in rxs {
            rx.recv().expect("answered").expect("drained to a real result");
        }
        per_submit
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "identical submit sequences must route identically");
    // exact ties fill in index order: 0,1,2,0,1,2
    assert_eq!(a[0], vec![1, 0, 0]);
    assert_eq!(a[2], vec![1, 1, 1]);
    assert_eq!(a[5], vec![2, 2, 2]);
}

/// Satellite (ISSUE 8): a queued request that outlives its admission
/// deadline is shed with a typed error — counted as a shed (not a
/// rejection), attributed to its tenant class, and the worker keeps
/// serving afterwards.
#[test]
fn deadline_missed_requests_are_shed_with_typed_error() {
    let cfg = AdmissionConfig {
        deadline: std::time::Duration::from_millis(10),
        // frozen pacing: the request can never be admitted, so the
        // deadline is guaranteed to fire
        tenant_rate: 1e-9,
        tenant_burst: 0.0,
        ..AdmissionConfig::default()
    };
    let server = synthetic_server(1, cfg);
    let gen = ScenarioGenerator::new(SimConfig::default());
    let rx = server.submit_for_tenant(3, METHOD, request_for(gen.generate(1), 0, 1));
    let err = rx
        .recv()
        .expect("a shed must be answered, not dropped")
        .expect_err("the deadline must shed this request");
    assert!(format!("{err:#}").contains("shed"), "{err:#}");
    assert!(format!("{err:#}").contains("deadline"), "{err:#}");
    assert_eq!(server.stats.queue_sheds.get(), 1);
    assert_eq!(server.stats.tenants.shed_count(3), 1);
    assert_eq!(
        server.stats.queue_rejections.get(),
        0,
        "sheds and rejections are separate outcomes"
    );
    assert_eq!(server.stats.shards[0].shed.get(), 1);
    assert_eq!(server.stats.requests_failed.get(), 0, "a shed is not a failure");
    assert_eq!(server.stats.shards[0].inflight.get(), 0);
    assert_eq!(server.stats.shards[0].live.get(), 1, "worker survives the shed");
}
