//! Property suite for the session wire codec (ISSUE 10 satellite):
//! random sessions across all three [`CachePrecision`] tiers must
//!
//! * round-trip encode -> decode **losslessly** — the rebuilt cache
//!   emits bit-identically to the original and re-encodes to the same
//!   bytes (a bijection on the codec's image, which is what migration
//!   needs for bit-identical cross-process results);
//! * serialize to exactly the [`se2attn::attention::memmodel`] byte
//!   formulas plus the documented header overhead
//!   ([`session_header_bytes`]) — the wire size *is* the resident size,
//!   nothing hidden.
//!
//! Failures replay with `SE2ATTN_PROP_SEED` (see `se2attn::proplite`).

use std::sync::Arc;

use se2attn::attention::memmodel::{map_tokens_bytes, window_cache_bytes};
use se2attn::config::{CachePrecision, Method, ModelConfig, SimConfig};
use se2attn::coordinator::kvcache::{MapTokens, SessionKey, WindowCache};
use se2attn::coordinator::session_codec::{
    decode_session, encode_session, session_blob_bytes, session_header_bytes,
};
use se2attn::proplite::check;
use se2attn::sim::ScenarioGenerator;
use se2attn::tokenizer::Tokenizer;

/// Random real-scenario session: a window slice of a generated scenario
/// at a random offset, cached at `precision`.
fn random_session(
    rng: &mut se2attn::prng::Rng,
    precision: CachePrecision,
) -> (Tokenizer, SessionKey, WindowCache) {
    let sim = SimConfig::default();
    let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
    let s = ScenarioGenerator::new(sim.clone()).generate(rng.below(10_000) as u64);
    let h = sim.history_steps;
    let t0 = h - 1 + rng.below(s.n_steps() - h + 1);
    let window: Vec<_> = (t0 + 1 - h..=t0).map(|t| s.states[t].clone()).collect();
    let map = Arc::new(MapTokens::tokenize(&tok, &s.map_elements));
    let cache = WindowCache::from_window_with(&tok, map, &window, precision).unwrap();
    let key = SessionKey {
        scene: s.scene_id(),
        t0: t0 as u32,
        sample: rng.below(64) as u32,
    };
    (tok, key, cache)
}

#[test]
fn roundtrip_is_lossless_across_all_precision_tiers() {
    check("session codec roundtrip", 24, |rng| {
        let precision = *rng.choice(&CachePrecision::ALL);
        let method = rng.choice(&Method::ALL).name();
        let (tok, key, cache) = random_session(rng, precision);
        let blob = encode_session(method, key, &cache);

        let (back_key, back) = decode_session(&blob, method)
            .map_err(|e| format!("{precision:?}: decode failed: {e:#}"))?;
        if back_key != key {
            return Err(format!("key changed: {back_key:?} vs {key:?}"));
        }
        if back.precision() != precision {
            return Err(format!(
                "precision changed: {:?} vs {precision:?}",
                back.precision()
            ));
        }

        // lossless: the rebuilt cache emits bit-identically
        let want = cache.emit(&tok).map_err(|e| e.to_string())?;
        let got = back.emit(&tok).map_err(|e| e.to_string())?;
        if got.feat != want.feat {
            return Err(format!("{precision:?}: emitted features diverged"));
        }
        if got.pose != want.pose || got.tq != want.tq || got.frame != want.frame {
            return Err(format!("{precision:?}: emitted poses/tq/frame diverged"));
        }

        // bijection on the image: re-encoding the decoded session
        // reproduces the original bytes
        let again = encode_session(method, back_key, &back);
        if again != blob {
            return Err(format!(
                "{precision:?}: re-encode diverged ({} vs {} bytes)",
                again.len(),
                blob.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn blob_size_equals_memmodel_plus_documented_header() {
    check("session codec size vs memmodel", 24, |rng| {
        let precision = *rng.choice(&CachePrecision::ALL);
        let method = rng.choice(&Method::ALL).name();
        let (_, key, cache) = random_session(rng, precision);
        let blob = encode_session(method, key, &cache);

        let body = map_tokens_bytes(cache.map().len(), cache.feat_dim())
            + window_cache_bytes(
                cache.n_agents(),
                cache.history_steps(),
                cache.feat_dim(),
                precision,
            );
        let want = session_header_bytes(method) + body;
        if blob.len() != want {
            return Err(format!(
                "{precision:?}: blob {} bytes, memmodel + header says {want} \
                 (header {}, body {body})",
                blob.len(),
                session_header_bytes(method)
            ));
        }
        // the helper the serving path uses agrees
        if blob.len()
            != session_blob_bytes(
                method,
                cache.map().len(),
                cache.n_agents(),
                cache.history_steps(),
                cache.feat_dim(),
                precision,
            )
        {
            return Err("session_blob_bytes disagrees with the encoder".into());
        }
        // quantized sessions actually halve the dominant row term
        if precision.is_quantized() {
            let f32_body = window_cache_bytes(
                cache.n_agents(),
                cache.history_steps(),
                cache.feat_dim(),
                CachePrecision::F32,
            );
            let q_body = window_cache_bytes(
                cache.n_agents(),
                cache.history_steps(),
                cache.feat_dim(),
                precision,
            );
            if q_body >= f32_body {
                return Err(format!(
                    "{precision:?}: quantized window bytes {q_body} not below \
                     f32 {f32_body}"
                ));
            }
        }
        Ok(())
    });
}
