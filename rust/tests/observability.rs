//! Observability end-to-end tests (ISSUE 6): a traced synthetic server
//! run must export a Chrome `trace_event` timeline that reconstructs
//! every request's route -> enqueue -> batch -> tokenize -> decode ->
//! attend -> respond path across worker shards, the metrics snapshot
//! must agree exactly with the recorded events under an 8-thread
//! hammer, and the Prometheus exposition must round-trip through the
//! JSON snapshot format.
//!
//! The server runs on [`NativeSdpaDecoder`] — the artifact-free backend
//! that drives the real blocked flash kernel — so Attend spans and the
//! kernel profiling counters come from the production code path.

use std::sync::Arc;

use se2attn::config::{Method, ModelConfig, SimConfig, SystemConfig};
use se2attn::coordinator::telemetry::ServerStats;
use se2attn::coordinator::{
    AdmissionConfig, Backend, BackendFactory, NativeSdpaDecoder, RolloutRequest, Router,
    ServeConfig, Server,
};
use se2attn::jsonio::Json;
use se2attn::metrics_export::{validate_prometheus, MetricsSnapshot};
use se2attn::sim::ScenarioGenerator;
use se2attn::trace::Stage;

const METHOD: Method = Method::Se2Fourier;

fn native_factory(n_actions: usize) -> BackendFactory {
    let kernel = se2attn::attention::kernel::KernelConfig::fixed(16, 8, 1);
    Arc::new(move |_shard: usize| -> anyhow::Result<Backend> {
        let mut backend: Backend = Router::new();
        backend.deploy(METHOD, Box::new(NativeSdpaDecoder::new(n_actions, kernel)));
        Ok(backend)
    })
}

fn traced_server(workers: usize) -> Server {
    let model = ModelConfig::synthetic();
    let n_actions = model.n_actions;
    let cfg = SystemConfig {
        artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
        model,
        sim: SimConfig::default(),
        threads: 1,
    };
    let mut serve = ServeConfig::with_workers(workers);
    serve.workers = workers;
    // a 2-session step batch keeps two traced requests sharing one decode
    // step, so per-slot trace attribution inside shared batches is covered
    serve.admission = AdmissionConfig {
        max_queue: 256,
        max_live_sessions: 2,
        ..AdmissionConfig::default()
    };
    serve.trace.enabled = true;
    serve.trace.ring_spans = 4096;
    serve.profile.enabled = true;
    Server::start_with_backend(cfg, vec![METHOD], serve, native_factory(n_actions))
        .expect("traced server start")
}

/// The headline end-to-end check: serve a traced workload pinned to both
/// shards, then reconstruct per-request timelines from the exported
/// Chrome trace and cross-check the metrics snapshot.
#[test]
fn traced_run_reconstructs_per_request_timelines_across_shards() {
    let server = traced_server(2);
    let sim = SimConfig::default();
    let gen = ScenarioGenerator::new(sim.clone());

    // pick seeds until session-affinity routing covers both shards
    let mut picked = Vec::new();
    let mut per_shard = [0usize; 2];
    let mut seed = 0u64;
    while picked.len() < 6 {
        let s = gen.generate(700 + seed);
        seed += 1;
        let shard = server.shard_for(&s);
        if per_shard[shard] < 3 {
            per_shard[shard] += 1;
            picked.push(s);
        }
    }
    assert_eq!(per_shard, [3, 3], "workload must cover both shards");

    let mut pending = Vec::new();
    for (i, scenario) in picked.into_iter().enumerate() {
        pending.push(server.submit(
            METHOD,
            RolloutRequest {
                scenario,
                t0: sim.history_steps - 1,
                n_samples: 1,
                temperature: 1.0,
                seed: i as i32,
            },
        ));
    }
    for rx in pending {
        rx.recv().expect("server alive").expect("rollout ok");
    }

    // join the workers so every span (incl. the final Batch/Respond) has
    // landed before the rings are drained
    let tracer = server.tracer().expect("tracing enabled").clone();
    let stats = Arc::clone(&server.stats);
    drop(server);

    // the export must survive a serialize -> parse round trip
    let doc = Json::parse(&tracer.to_chrome_trace().to_string()).expect("trace json parses");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let recorded = doc
        .get("otherData")
        .and_then(|o| o.get("spans_recorded"))
        .and_then(|n| n.as_f64())
        .unwrap_or(0.0);
    assert!(recorded > 0.0, "no spans recorded");

    // stage name -> count, and trace id -> stages + (first ts, last ts)
    let mut stage_counts: std::collections::BTreeMap<&str, usize> =
        std::collections::BTreeMap::new();
    let mut by_trace: std::collections::BTreeMap<u64, Vec<(&str, f64, usize)>> =
        std::collections::BTreeMap::new();
    let mut shard_tracks: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph != "X" && ph != "i" {
            continue;
        }
        let name = ev.get("name").and_then(|n| n.as_str()).expect("event name");
        let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("event ts");
        let tid = ev.get("tid").and_then(|t| t.as_usize()).expect("event tid");
        let trace = ev
            .get("args")
            .and_then(|a| a.get("trace"))
            .and_then(|t| t.as_f64())
            .unwrap_or(0.0) as u64;
        *stage_counts.entry(name).or_insert(0) += 1;
        if trace > 0 {
            by_trace.entry(trace).or_default().push((name, ts, tid));
        }
        if tid >= 1 {
            shard_tracks.insert(tid);
        }
    }
    for stage in Stage::PIPELINE {
        assert!(
            stage_counts.get(stage.name()).copied().unwrap_or(0) > 0,
            "no {} spans in the trace",
            stage.name()
        );
    }
    assert!(
        shard_tracks.len() >= 2,
        "spans must land on both shard tracks, got {shard_tracks:?}"
    );

    // every traced request reconstructs its full pipeline, in order
    assert_eq!(by_trace.len(), 6, "one timeline per request");
    for (trace, spans) in &by_trace {
        let stages: std::collections::BTreeSet<&str> =
            spans.iter().map(|(name, _, _)| *name).collect();
        for need in ["route", "enqueue", "tokenize", "decode", "attend", "respond"] {
            assert!(stages.contains(need), "request {trace} is missing {need}");
        }
        let ts_of = |stage: &str| -> f64 {
            spans
                .iter()
                .filter(|(name, _, _)| *name == stage)
                .map(|(_, ts, _)| *ts)
                .fold(f64::NAN, f64::max)
        };
        assert!(
            ts_of("respond") >= ts_of("route"),
            "request {trace}: respond before route"
        );
        // route is frontend-side (track 0), the rest shard-side
        let route_track = spans
            .iter()
            .find(|(name, _, _)| *name == "route")
            .map(|(_, _, tid)| *tid)
            .unwrap();
        assert_eq!(route_track, 0, "route spans live on the frontend track");
        assert!(
            spans.iter().any(|(name, _, tid)| *name == "decode" && *tid >= 1),
            "request {trace}: decode must run on a shard track"
        );
    }

    // metrics snapshot agrees with the run and the profiling counters saw
    // real kernel work (NativeSdpaDecoder drives flash_sdpa_blocked)
    assert_eq!(stats.requests_done.get(), 6);
    let snap = MetricsSnapshot::collect(&stats, Some(&tracer));
    let scalar = |name: &str| -> u64 {
        snap.scalars
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.value)
            .sum()
    };
    assert_eq!(scalar("se2attn_requests_done_total"), 6);
    assert!(scalar("se2attn_trace_spans_recorded_total") > 0);
    assert!(scalar("se2attn_kernel_calls_total") > 0, "profiling counters idle");
    let e2e = snap
        .histograms
        .iter()
        .find(|h| h.name == "se2attn_e2e_latency_us")
        .expect("e2e histogram exported");
    assert_eq!(e2e.count, 6);
    assert_eq!(e2e.buckets.iter().sum::<u64>(), 6);
    validate_prometheus(&snap.to_prometheus()).expect("exposition valid");
}

/// Satellite: hammer the histogram + counters from 8 threads while a 9th
/// snapshots concurrently; exported totals must equal the recorded
/// events exactly (count == sum of buckets, exact min/max).
#[test]
fn concurrent_recording_and_snapshots_stay_exact() {
    const THREADS: u64 = 8;
    const PER: u64 = 5_000;
    let stats = Arc::new(ServerStats::with_shards(1));
    let mut handles = Vec::new();
    for _ in 0..THREADS {
        let s = Arc::clone(&stats);
        handles.push(std::thread::spawn(move || {
            for i in 1..=PER {
                s.requests_in.inc();
                s.e2e_latency.record_us(i);
                s.decode_latency.record_us(1 + i % 4096);
            }
        }));
    }
    let snapper = {
        let s = Arc::clone(&stats);
        std::thread::spawn(move || {
            // mid-flight snapshots must always be internally valid
            for _ in 0..50 {
                let snap = MetricsSnapshot::collect(&s, None);
                validate_prometheus(&snap.to_prometheus()).expect("mid-flight exposition valid");
                std::thread::yield_now();
            }
        })
    };
    for h in handles {
        h.join().unwrap();
    }
    snapper.join().unwrap();

    let total = THREADS * PER;
    assert_eq!(stats.requests_in.get(), total);
    assert_eq!(stats.e2e_latency.count(), total);
    assert_eq!(stats.e2e_latency.bucket_counts().iter().sum::<u64>(), total);
    assert_eq!(stats.e2e_latency.min_us(), 1);
    assert_eq!(stats.e2e_latency.max_us(), PER);
    assert_eq!(stats.e2e_latency.sum_us(), THREADS * PER * (PER + 1) / 2);

    let snap = MetricsSnapshot::collect(&stats, None);
    let requests = snap
        .scalars
        .iter()
        .find(|s| s.name == "se2attn_requests_in_total")
        .unwrap();
    assert_eq!(requests.value, total);
    for name in ["se2attn_e2e_latency_us", "se2attn_decode_latency_us"] {
        let h = snap.histograms.iter().find(|h| h.name == name).unwrap();
        assert_eq!(h.count, total, "{name} count");
        assert_eq!(h.buckets.iter().sum::<u64>(), total, "{name} buckets");
    }
}

/// Satellite: the JSON snapshot round-trips losslessly and re-renders to
/// an identical, validator-clean Prometheus exposition.
#[test]
fn snapshot_roundtrip_preserves_prometheus_exposition() {
    let stats = ServerStats::with_shards(2);
    stats.requests_in.add(17);
    stats.requests_done.add(16);
    stats.e2e_latency.record_us(250);
    stats.e2e_latency.record_us(80_000);
    stats.decode_latency.record_us(1_024);
    stats.shards[1].requests.add(9);

    let snap = MetricsSnapshot::collect(&stats, None);
    let text = snap.to_json().to_string();
    let back = MetricsSnapshot::from_json(&Json::parse(&text).expect("snapshot json parses"))
        .expect("snapshot deserializes");
    assert_eq!(snap, back);
    let exposition = back.to_prometheus();
    assert_eq!(exposition, snap.to_prometheus());
    let samples = validate_prometheus(&exposition).expect("round-tripped exposition valid");
    assert!(samples > 0);
}
