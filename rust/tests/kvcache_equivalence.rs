//! Cache-correctness integration tests for the incremental decode
//! subsystem (no PJRT needed): the acceptance properties of DESIGN.md §10.
//!
//! 1. Incremental decode output == full-window recompute within 1e-5,
//!    stepped across a real scenario's sliding window.
//! 2. Outputs stay invariant when the whole scene *and the cached state*
//!    are re-anchored under a random global SE(2) transform.
//! 3. The serving tokenization cache is bit-identical to full
//!    re-tokenization through an entire simulated rollout.

use std::sync::Arc;

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::{linear, AttnProblem};
use se2attn::config::{Method, ModelConfig, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::prng::Rng;
use se2attn::sim::{AgentState, ScenarioGenerator};
use se2attn::tokenizer::Tokenizer;

fn test_model_config(sim: &SimConfig) -> ModelConfig {
    ModelConfig {
        n_tokens: sim.tokens_per_scene(),
        ..ModelConfig::synthetic()
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Streaming decode over a growing token set equals Algorithm 2 recomputed
/// from scratch at every step, within 1e-5.
#[test]
fn incremental_decode_matches_full_recompute() {
    let (d, f) = (12usize, 16usize);
    let scales = vec![1.0, 0.5];
    let mut rng = Rng::new(314);
    let steps = 10usize;
    let per_step = 6usize;

    let mut eng = IncrementalAttention::new(IncrementalConfig {
        method: Method::Se2Fourier,
        d,
        fourier_f: f,
        scales: scales.clone(),
        kernel: KernelConfig::default(),
        precision: se2attn::config::CachePrecision::F32,
    });
    let mut all_k: Vec<f32> = Vec::new();
    let mut all_v: Vec<f32> = Vec::new();
    let mut all_poses: Vec<Pose> = Vec::new();
    let mut all_t: Vec<i32> = Vec::new();

    for step in 0..steps {
        let k: Vec<f32> = (0..per_step * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..per_step * d).map(|_| rng.normal() as f32).collect();
        let poses: Vec<Pose> = (0..per_step)
            .map(|_| Pose::new(rng.range(-1.5, 1.5), rng.range(-1.5, 1.5), rng.range(-3.1, 3.1)))
            .collect();
        let t = vec![step as i32; per_step];
        eng.append(&k, &v, &poses, &t);
        all_k.extend_from_slice(&k);
        all_v.extend_from_slice(&v);
        all_poses.extend_from_slice(&poses);
        all_t.extend_from_slice(&t);

        // frontier queries = this step's tokens
        let q: Vec<f32> = (0..per_step * d).map(|_| rng.normal() as f32).collect();
        let got = eng.attend(&q, &poses, &t).out;
        let want = linear::attention(&AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: &scales,
            q: &q,
            k: &all_k,
            v: &all_v,
            pose_q: &poses,
            pose_k: &all_poses,
            tq: &t,
            tk: &all_t,
        })
        .out;
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-5, "step {step}: cached vs recompute diff {err}");
    }
}

/// Re-anchoring the cached state under a random global SE(2) transform
/// leaves decode outputs unchanged within 1e-5, and the re-anchored cache
/// agrees with a full recompute in the new frame within 1e-5.
#[test]
fn incremental_decode_invariant_under_random_re_anchor() {
    let (d, f) = (12usize, 24usize);
    let scales = vec![1.0, 0.5];
    let mut rng = Rng::new(2718);
    for trial in 0..5 {
        let m = 24usize;
        let n = 6usize;
        let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let pk: Vec<Pose> = (0..m)
            .map(|_| Pose::new(rng.range(-1.2, 1.2), rng.range(-1.2, 1.2), rng.range(-3.1, 3.1)))
            .collect();
        let pq: Vec<Pose> = (0..n)
            .map(|_| Pose::new(rng.range(-1.2, 1.2), rng.range(-1.2, 1.2), rng.range(-3.1, 3.1)))
            .collect();
        let tk: Vec<i32> = (0..m).map(|i| (i / 6) as i32).collect();
        let tq = vec![9i32; n];
        let g = Pose::new(rng.range(-0.8, 0.8), rng.range(-0.8, 0.8), rng.range(-3.1, 3.1));

        let cfg = IncrementalConfig {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: scales.clone(),
            kernel: KernelConfig::default(),
            precision: se2attn::config::CachePrecision::F32,
        };
        let mut eng = IncrementalAttention::new(cfg);
        eng.append(&k, &v, &pk, &tk);
        let before = eng.attend(&q, &pq, &tq).out;

        // re-anchor the whole scene AND the cached state by g
        eng.re_anchor(&g).expect("se2fourier re-anchor");
        let pq_new: Vec<Pose> = pq.iter().map(|p| g.compose(p)).collect();
        let after = eng.attend(&q, &pq_new, &tq).out;
        let err = max_abs_diff(&before, &after);
        assert!(err < 1e-5, "trial {trial}: invariance diff {err}");

        // and the cached path agrees with recomputing in the new frame
        let pk_new: Vec<Pose> = pk.iter().map(|p| g.compose(p)).collect();
        let recomputed = linear::attention(&AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: &scales,
            q: &q,
            k: &k,
            v: &v,
            pose_q: &pq_new,
            pose_k: &pk_new,
            tq: &tq,
            tk: &tk,
        })
        .out;
        let err = max_abs_diff(&after, &recomputed);
        assert!(err < 1e-5, "trial {trial}: cached vs recomputed diff {err}");
    }
}

/// Walk a full simulated rollout window: the pool's cached tokenization
/// must stay bit-identical to full re-tokenization at every decode step,
/// with the first step a miss and every later step a hit.
#[test]
fn pool_tokenization_matches_full_across_rollout() {
    let sim = SimConfig::default();
    let tok = Tokenizer::new(&test_model_config(&sim), &sim);
    let gen = ScenarioGenerator::new(sim.clone());
    let stats = Arc::new(CacheStats::default());
    let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));

    for seed in [1u64, 2] {
        let s = gen.generate(seed);
        let h = sim.history_steps;
        for sample in 0..2u32 {
            let key = SessionKey { scene: s.seed, t0: h as u32 - 1, sample };
            let mut window: Vec<Vec<AgentState>> =
                (0..h).map(|t| s.states[t].clone()).collect();
            for t in h..s.n_steps() {
                let got = pool.step(key, &tok, &s.map_elements, &window).unwrap();
                let want = tok.tokenize_window(&s.map_elements, &window, None);
                assert_eq!(got.feat, want.feat, "seed {seed} sample {sample} step {t}");
                assert_eq!(got.pose, want.pose, "seed {seed} sample {sample} step {t}");
                assert_eq!(got.tq, want.tq);
                assert_eq!(got.target, want.target);
                window.remove(0);
                window.push(s.states[t].clone());
            }
            pool.end_session(key);
        }
    }
    // 2 scenes x 2 samples: one miss each, everything else hits; map rows
    // tokenized once per scene.
    assert_eq!(stats.misses.get(), 4);
    assert!(stats.hits.get() > 0);
    assert_eq!(stats.map_misses.get(), 2);
    assert!(stats.map_hits.get() >= 2);
    assert_eq!(pool.live_sessions(), 0);
}
