//! Multi-process serving tests (ISSUE 10): real worker *processes*
//! spawned from the built `se2-attention` binary, speaking the
//! length-prefixed wire protocol to a [`ProcServer`] coordinator.
//!
//! The headline invariant is the same one `shard_serving.rs` proves for
//! in-process shards, extended across a process boundary and through
//! faults: per-request results are **bit-identical** to the
//! single-process reference even when a worker is SIGKILLed mid-rollout
//! (envelopes replay from `t0` with the same pure-function step seeds),
//! drained mid-rollout (sessions migrate as lossless KV blobs), or cut
//! off behind a partitioned / delayed socket.
//!
//! Workers run the [`SyntheticDecoder`] with a nonzero spin-work knob so
//! requests stay in flight long enough for the fault to land; the
//! in-process reference deploys the *same* decoder configuration because
//! `work_per_token` feeds the action hash.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use se2attn::config::{scenario_mix, Method, ModelConfig, ProcConfig, SimConfig, SystemConfig};
use se2attn::coordinator::{
    shard_of, AdmissionConfig, Backend, BackendFactory, CacheConfig, ProcServer, RolloutRequest,
    RolloutResult, Router, ServeConfig, Server, SyntheticDecoder,
};
use se2attn::sim::{MixGenerator, Scenario};

mod common;
use common::procfleet::{self, ChaosProxy};

const METHOD: Method = Method::Se2Fourier;

/// Spin-work per decoded token: large enough that a multi-scene workload
/// is still mid-rollout when the fault lands, small enough that a full
/// pass stays well under a second per request.
const WORK: usize = 20_000;

fn test_system_config() -> SystemConfig {
    SystemConfig {
        artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
        model: ModelConfig::synthetic(),
        sim: SimConfig::default(),
        threads: 1,
    }
}

fn admission() -> AdmissionConfig {
    AdmissionConfig {
        max_queue: 1024,
        ..AdmissionConfig::default()
    }
}

/// Single-process reference: in-process shards running the same decoder
/// configuration the worker processes deploy.
fn reference_server(workers: usize, work: usize) -> Server {
    let n_actions = ModelConfig::synthetic().n_actions;
    let factory: BackendFactory = Arc::new(move |_shard: usize| -> anyhow::Result<Backend> {
        let mut backend: Backend = Router::new();
        backend.deploy(METHOD, Box::new(SyntheticDecoder::with_work(n_actions, work)));
        Ok(backend)
    });
    Server::start_with_backend(
        test_system_config(),
        vec![METHOD],
        ServeConfig {
            workers,
            admission: admission(),
            cache: CacheConfig::default(),
            kernel: se2attn::attention::kernel::KernelConfig::default(),
            ..ServeConfig::default()
        },
        factory,
    )
    .expect("reference server start")
}

/// A coordinator that spawns and supervises `workers` real child
/// processes from the built binary.
fn proc_fleet(workers: usize, work: usize, cfg: ProcConfig) -> ProcServer {
    ProcServer::start(
        workers,
        cfg,
        admission(),
        procfleet::synthetic_worker_cmd(METHOD.name(), work),
    )
    .expect("proc fleet start")
}

fn request_for(scenario: Scenario, i: usize, n_samples: usize) -> RolloutRequest {
    let sim = SimConfig::default();
    RolloutRequest {
        scenario,
        t0: sim.history_steps - 1,
        n_samples,
        temperature: 1.0,
        seed: i as i32,
    }
}

/// Mixed-family scenarios, seeds `1000 + i` (matches `shard_serving.rs`
/// so the workload shape is the one the in-process suite already pins).
fn mixed_scenarios(scenes: usize) -> Vec<Scenario> {
    let gen = MixGenerator::new(SimConfig::default(), scenario_mix("mixed", "").unwrap());
    (0..scenes).map(|i| gen.generate(1000 + i as u64)).collect()
}

/// Scenarios whose affinity hash pins every request to worker `want` of
/// an `n_workers` fleet — the deterministic way to aim a workload at the
/// worker a test is about to kill, drain, or partition.
fn pinned_scenarios(scenes: usize, want: usize, n_workers: usize) -> Vec<Scenario> {
    let gen = MixGenerator::new(SimConfig::default(), scenario_mix("mixed", "").unwrap());
    let mut out = Vec::new();
    for seed in 0..20_000u64 {
        let s = gen.generate(seed);
        if shard_of(s.scene_id(), n_workers) == want {
            out.push(s);
            if out.len() == scenes {
                return out;
            }
        }
    }
    panic!("no {scenes} scenarios pinned to worker {want}/{n_workers} in 20k seeds");
}

fn gather(rxs: Vec<mpsc::Receiver<anyhow::Result<RolloutResult>>>) -> Vec<RolloutResult> {
    rxs.into_iter()
        .enumerate()
        .map(|(i, rx)| {
            rx.recv_timeout(Duration::from_secs(120))
                .unwrap_or_else(|_| panic!("request {i}: coordinator dropped or timed out"))
                .unwrap_or_else(|e| panic!("request {i}: rollout failed: {e}"))
        })
        .collect()
}

fn run_inproc(server: &Server, scenarios: &[Scenario], n_samples: usize) -> Vec<RolloutResult> {
    let rxs = scenarios
        .iter()
        .enumerate()
        .map(|(i, s)| server.submit(METHOD, request_for(s.clone(), i, n_samples)))
        .collect();
    gather(rxs)
}

fn submit_procs(
    fleet: &ProcServer,
    scenarios: &[Scenario],
    n_samples: usize,
) -> Vec<mpsc::Receiver<anyhow::Result<RolloutResult>>> {
    scenarios
        .iter()
        .enumerate()
        .map(|(i, s)| fleet.submit(METHOD, request_for(s.clone(), i, n_samples)))
        .collect()
}

/// Bit-identical per-request results; `decode_ms` is wall-clock and
/// excluded.
fn assert_same_results(reference: &[RolloutResult], got: &[RolloutResult]) {
    assert_eq!(reference.len(), got.len());
    for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
        assert_eq!(a.trajectories, b.trajectories, "request {i}: trajectories");
        assert_eq!(a.min_ade, b.min_ade, "request {i}: minADE");
        assert_eq!(a.classes, b.classes, "request {i}: classes");
        assert_eq!(a.collisions, b.collisions, "request {i}: collisions");
    }
}

fn wait_until(ms: u64, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Acceptance gate: the same mixed workload through two worker
/// *processes* and through the single-process path must produce
/// identical results, with requests spread across workers exactly as the
/// affinity hash predicts.
#[test]
fn two_proc_results_match_single_process() {
    let scenes = 16;
    let samples = 2;
    let scenarios = mixed_scenarios(scenes);

    let reference = {
        let server = reference_server(1, WORK);
        run_inproc(&server, &scenarios, samples)
    };

    let fleet = proc_fleet(2, WORK, ProcConfig::default());
    let results = gather(submit_procs(&fleet, &scenarios, samples));
    assert_same_results(&reference, &results);

    let stats = fleet.stats();
    let mut expected = [0u64; 2];
    for s in &scenarios {
        expected[shard_of(s.scene_id(), 2)] += 1;
    }
    for (i, sh) in stats.shards.iter().enumerate() {
        assert_eq!(sh.requests.get(), expected[i], "worker {i} request count");
    }
    assert!(
        expected.iter().all(|&c| c > 0),
        "workload must hit both workers: {expected:?}"
    );
    assert_eq!(stats.requests_failed.get(), 0);
    assert_eq!(stats.migration.wire_errors.get(), 0);
}

/// SIGKILL a worker mid-rollout under an open-loop load: zero lost
/// sessions — every request still answers, and bit-identically to the
/// single-process reference, because replayed envelopes restart from
/// `t0` with the same pure-function step seeds.
#[test]
fn sigkill_mid_rollout_loses_nothing() {
    let scenes = 12;
    let samples = 2;
    let scenarios = mixed_scenarios(scenes);

    let reference = {
        let server = reference_server(1, WORK);
        run_inproc(&server, &scenarios, samples)
    };

    let fleet = proc_fleet(2, WORK, ProcConfig::default());
    let stats = fleet.stats();
    // wait for both workers to finish the handshake so the kill hits a
    // live, request-holding process rather than a not-yet-spawned one
    assert!(
        wait_until(10_000, || stats.shards.iter().all(|s| s.live.get() == 1)),
        "workers never connected"
    );

    let rxs = submit_procs(&fleet, &scenarios, samples);
    let victim = fleet.worker_pid(0).expect("worker 0 has a child process");
    procfleet::sigkill(victim);

    let results = gather(rxs);
    assert_same_results(&reference, &results);

    assert!(
        stats.migration.worker_deaths.get() >= 1,
        "the SIGKILL must be detected as a worker death"
    );
    assert_eq!(stats.requests_failed.get(), 0, "zero lost sessions");
    // default config respawns: the fleet is back at full strength
    assert!(
        wait_until(10_000, || stats.shards.iter().all(|s| s.live.get() == 1)),
        "killed worker never respawned"
    );
    assert!(stats.migration.worker_respawns.get() >= 1);
}

/// Graceful drain mid-rollout: the drained worker exports its live
/// sessions as KV blobs, the coordinator re-targets them at a survivor,
/// and the survivor resumes mid-trajectory — results still bit-identical
/// (the session codec round-trip is lossless, proven property-wise in
/// `session_codec_props.rs`).
#[test]
fn drain_migrates_sessions_without_loss() {
    let scenes = 8;
    let samples = 2;
    let scenarios = pinned_scenarios(scenes, 0, 2);

    let reference = {
        let server = reference_server(1, WORK);
        run_inproc(&server, &scenarios, samples)
    };

    // the drain races the rollout: retry with a fresh fleet until the
    // drain lands while sessions are live (first try in practice — WORK
    // keeps each request in flight for many scheduler steps)
    let mut migrated = 0u64;
    for _attempt in 0..5 {
        let fleet = proc_fleet(2, WORK, ProcConfig::default());
        let stats = fleet.stats();
        assert!(
            wait_until(10_000, || stats.shards.iter().all(|s| s.live.get() == 1)),
            "workers never connected"
        );
        let rxs = submit_procs(&fleet, &scenarios, samples);
        fleet.drain_worker(0);
        let results = gather(rxs);
        assert_same_results(&reference, &results);
        assert_eq!(stats.requests_failed.get(), 0, "zero lost sessions");
        assert_eq!(
            stats.migration.worker_deaths.get(),
            0,
            "a clean drain is not a death"
        );
        migrated = stats.migration.sessions_migrated.get();
        if migrated > 0 {
            assert!(stats.migration.migration_bytes.get() > 0);
            break;
        }
    }
    assert!(migrated > 0, "drain never caught a live session in 5 tries");
}

/// A slow link is not a fault: with 20 ms injected on every relayed
/// chunk the worker still heartbeats inside `death_after`, requests
/// complete, and the wire-error counter stays untouched.
#[test]
fn delayed_socket_still_completes() {
    let scenes = 6;
    let samples = 2;
    let scenarios = mixed_scenarios(scenes);

    let reference = {
        let server = reference_server(1, WORK);
        run_inproc(&server, &scenarios, samples)
    };

    let cfg = ProcConfig {
        manual_workers: true,
        ..ProcConfig::default()
    };
    let fleet = proc_fleet(1, WORK, cfg);
    let proxy = ChaosProxy::start(fleet.addr()).expect("proxy start");
    proxy.set_delay_ms(20);
    fleet
        .spawn_worker_via(0, &proxy.addr().to_string())
        .expect("spawn worker through proxy");

    let stats = fleet.stats();
    assert!(
        wait_until(10_000, || stats.shards[0].live.get() == 1),
        "worker never connected through the proxy"
    );
    let results = gather(submit_procs(&fleet, &scenarios, samples));
    assert_same_results(&reference, &results);
    assert_eq!(stats.migration.wire_errors.get(), 0);
    assert_eq!(stats.migration.worker_deaths.get(), 0);
}

/// A partition (connection open, zero bytes flowing) is detected by the
/// heartbeat liveness sweep — not by a socket error — and the stranded
/// envelopes replay to the surviving worker.
#[test]
fn partition_triggers_replay_to_survivor() {
    let scenes = 6;
    let samples = 2;
    let scenarios = pinned_scenarios(scenes, 0, 2);

    let reference = {
        let server = reference_server(1, WORK);
        run_inproc(&server, &scenarios, samples)
    };

    let cfg = ProcConfig {
        heartbeat: Duration::from_millis(50),
        death_after: Duration::from_millis(400),
        respawn: false,
        manual_workers: true,
        ..ProcConfig::default()
    };
    let fleet = proc_fleet(2, WORK, cfg);
    let proxy = ChaosProxy::start(fleet.addr()).expect("proxy start");
    fleet
        .spawn_worker_via(0, &proxy.addr().to_string())
        .expect("spawn worker 0 through proxy");
    fleet
        .spawn_worker_via(1, &fleet.addr().to_string())
        .expect("spawn worker 1 direct");

    let stats = fleet.stats();
    assert!(
        wait_until(10_000, || stats.shards.iter().all(|s| s.live.get() == 1)),
        "workers never connected"
    );

    // cut worker 0 off, then submit the load pinned to it: the envelopes
    // sit on the silent socket until the liveness sweep declares death
    proxy.pause();
    let rxs = submit_procs(&fleet, &scenarios, samples);
    let results = gather(rxs);
    assert_same_results(&reference, &results);

    assert_eq!(
        stats.migration.worker_deaths.get(),
        1,
        "exactly one death: the partitioned worker"
    );
    assert_eq!(stats.requests_failed.get(), 0, "zero lost sessions");
    assert!(stats.migration.envelopes_replayed.get() >= 1);
    assert_eq!(stats.shards[1].live.get(), 1, "survivor stays live");
}
