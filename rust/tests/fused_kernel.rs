//! Integration tests for the fused SE(2) kernel path (DESIGN.md §18):
//! accuracy against the scalar reference under ragged causal masks,
//! bit-stability across thread counts and against project-then-attend,
//! and the fused memory claim tied to the tracking allocator's measured
//! `kernel_scratch` scope.
//!
//! Scope discipline (same rule as `tests/obs_memory.rs`): within this
//! binary exactly one test asserts on `kernel_scratch` *bounds*
//! (`fused_scratch_measured_at_the_allocator`); its slack absorbs the
//! small per-thread scratch the sibling accuracy tests charge to the
//! same scope while running in parallel.

use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::{linear, memmodel, AttnProblem};
use se2attn::config::Method;
use se2attn::geometry::Pose;
use se2attn::obs::alloc::{self, Scope};
use se2attn::prng::Rng;

struct Data {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    pose_q: Vec<Pose>,
    pose_k: Vec<Pose>,
    tq: Vec<i32>,
    tk: Vec<i32>,
}

/// Ragged causal masking: non-uniform query timesteps (including one row
/// that sees no keys at all) against scattered key timesteps.
fn data(n: usize, m: usize, d: usize, seed: u64) -> Data {
    let mut rng = Rng::new(seed);
    let mut tq: Vec<i32> = (0..n).map(|i| (i % 5) as i32).collect();
    tq[0] = -1; // sees nothing: both paths must emit exact zeros
    Data {
        q: (0..n * d).map(|_| rng.normal() as f32).collect(),
        k: (0..m * d).map(|_| rng.normal() as f32).collect(),
        v: (0..m * d).map(|_| rng.normal() as f32).collect(),
        pose_q: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        pose_k: (0..m)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        tq,
        tk: (0..m).map(|j| ((j * 3) % 8) as i32).collect(),
    }
}

fn problem<'a>(method: Method, d: usize, f: usize, dat: &'a Data, scales: &'a [f64]) -> AttnProblem<'a> {
    AttnProblem {
        method,
        d,
        fourier_f: f,
        scales,
        q: &dat.q,
        k: &dat.k,
        v: &dat.v,
        pose_q: &dat.pose_q,
        pose_k: &dat.pose_k,
        tq: &dat.tq,
        tk: &dat.tk,
    }
}

/// Acceptance bar: the fused path matches `linear::attention_ref` within
/// 1e-5 under ragged causal masks, for every method.
#[test]
fn fused_matches_scalar_reference_for_every_method() {
    const D: usize = 12;
    const F: usize = 8;
    let scales = [1.0, 0.5, 0.25];
    let dat = data(9, 31, D, 41);
    let kcfg = KernelConfig::fixed(8, 8, 3);
    for method in Method::ALL {
        let p = problem(method, D, F, &dat, &scales);
        let fused = linear::attention_fused_with(&p, &kcfg);
        let reference = linear::attention_ref(&p);
        let worst = fused
            .out
            .iter()
            .zip(&reference.out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            worst <= 1e-5,
            "{method:?}: fused deviates from the scalar reference by {worst:e}"
        );
        // the empty row (tq = -1) must be exact zeros, not near-zeros
        assert!(
            fused.out[..D].iter().all(|&x| x == 0.0),
            "{method:?}: row with no visible keys must be exactly zero"
        );
    }
}

/// The fused execution is bit-identical to project-then-attend for the
/// same `{block_m, lanes}` — routing between them can never change
/// results, only the transient-memory / recompute trade.
#[test]
fn fused_is_bit_identical_to_project_then_attend() {
    const D: usize = 12;
    const F: usize = 8;
    let scales = [1.0, 0.5, 0.25];
    let dat = data(13, 47, D, 42);
    let kcfg = KernelConfig::fixed(16, 8, 2);
    for method in Method::ALL {
        let p = problem(method, D, F, &dat, &scales);
        let fused = linear::attention_fused_with(&p, &kcfg);
        let projected = linear::attention_projected_with(&p, &kcfg);
        assert_eq!(fused.out.len(), projected.out.len());
        for (i, (a, b)) in fused.out.iter().zip(&projected.out).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{method:?}: fused and projected diverge at element {i}: {a} vs {b}"
            );
        }
    }
}

/// Thread count partitions work but never reorders any per-row
/// reduction: the fused output is bit-identical from 1 to 8 workers.
#[test]
fn fused_is_bit_identical_across_thread_counts() {
    const D: usize = 12;
    const F: usize = 8;
    let scales = [1.0, 0.5, 0.25];
    // 16 query rows = 2 chunks, so multi-thread runs genuinely split work
    let dat = data(16, 64, D, 43);
    let p = problem(Method::Se2Fourier, D, F, &dat, &scales);
    let baseline = linear::attention_fused_with(&p, &KernelConfig::fixed(16, 8, 1));
    for threads in [2usize, 4, 8] {
        let got = linear::attention_fused_with(&p, &KernelConfig::fixed(16, 8, threads));
        for (i, (a, b)) in baseline.out.iter().zip(&got.out).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "threads={threads}: output diverges at element {i}"
            );
        }
    }
}

/// The memory claim, end to end (ISSUE 9 satellite): the fused path's
/// reported `peak_temp_bytes` equals the closed-form
/// `memmodel::linear_fused_bytes` transient, the tracking allocator's
/// measured `kernel_scratch` rise agrees with it, and project-then-attend
/// still carries the O(m·c) projection intermediates the fused path
/// eliminated.
#[test]
fn fused_scratch_measured_at_the_allocator() {
    const D: usize = 48;
    const F: usize = 12;
    const BLOCK_M: usize = 64;
    // contamination budget for sibling tests' small-c scratch (their
    // per-thread tiles are ~45 KiB; the regression guarded against here
    // is the ~13 MiB projected intermediate reappearing)
    const SLACK: u64 = 2 << 20;
    let scales = [1.0, 0.5, 0.25, 0.125];
    let (n, m) = (8usize, 4096usize);
    let dat = data(n, m, D, 44);
    let p = problem(Method::Se2Fourier, D, F, &dat, &scales);
    let c = linear::proj_dim(Method::Se2Fourier, D, F);
    // threads=1 executes inline on this test's thread: one participating
    // worker, whose scratch the fused driver tags `kernel_scratch`
    let kcfg = KernelConfig::fixed(BLOCK_M, 8, 1);

    alloc::reset_peak(Scope::KernelScratch);
    let base = alloc::snapshot(Scope::KernelScratch).live_bytes;
    let fused = linear::attention_fused_with(&p, &kcfg);
    let measured = alloc::snapshot(Scope::KernelScratch)
        .peak_bytes
        .saturating_sub(base);

    let model = memmodel::linear_fused_bytes(Method::Se2Fourier, n, m, D, F, BLOCK_M, 1);
    // all three accountings agree: kernel return == memmodel formula
    assert_eq!(
        fused.peak_temp_bytes, model.transient_bytes,
        "kernel scratch accounting drifted from memmodel::linear_fused_bytes"
    );
    // ... and the allocator actually saw the tiles (k~/v~ block pair is
    // the floor) but nothing approaching a projected intermediate
    let tile_floor = (2 * BLOCK_M * c * std::mem::size_of::<f32>()) as u64;
    assert!(
        measured >= tile_floor,
        "measured kernel_scratch rise {measured} B below the {tile_floor} B \
         k~/v~ tile pair — worker allocations lost the scope tag"
    );
    assert!(
        measured <= model.transient_bytes as u64 + SLACK,
        "measured kernel_scratch rise {measured} B exceeds the modeled \
         {} B + slack — an O(m·c) transient crept back into the fused path",
        model.transient_bytes
    );

    // project-then-attend, unchanged: its peak still carries the k~/v~
    // projection (>= 2·m·c·f32), which dwarfs the fused transient
    let projected = linear::attention_projected_with(&p, &kcfg);
    let projection_floor = 2 * m * c * std::mem::size_of::<f32>();
    assert!(
        projected.peak_temp_bytes >= projection_floor,
        "projected peak {} B lost its projection intermediates (floor {})",
        projected.peak_temp_bytes,
        projection_floor
    );
    assert!(
        fused.peak_temp_bytes * 4 < projected.peak_temp_bytes,
        "fused peak {} B is not well under the projected peak {} B",
        fused.peak_temp_bytes,
        projected.peak_temp_bytes
    );
}
