//! Memory-attribution integration tests (DESIGN.md §16): the tracking
//! allocator's measured bytes must agree with the closed-form
//! `memmodel` formulas, and the measured attention peaks must reproduce
//! the paper's linear-vs-quadratic memory separation at runtime.
//!
//! Scope discipline: within this binary each tagged scope is driven by
//! exactly one test (`kvcache`/`map_registry` by the cache test,
//! `kernel_scratch` by the N-sweep, `trace` by the executor test), so
//! the parallel test harness cannot cross-contaminate the counters.

use std::sync::{Arc, Mutex};

use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::{linear, memmodel, quadratic, AttnProblem};
use se2attn::config::{CachePrecision, Method, ModelConfig, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::obs::alloc::{self, MemScope, Scope};
use se2attn::obs::memreport;
use se2attn::prng::Rng;
use se2attn::sim::ScenarioGenerator;
use se2attn::tokenizer::Tokenizer;

// ---------------------------------------------------------------------------
// measured kvcache bytes vs the memmodel formula
// ---------------------------------------------------------------------------

/// Allocator-measured kvcache bytes for freshly built sessions must sit
/// within 10% of `memmodel::window_cache_bytes` — the tolerance covers
/// container headers (the `VecDeque` step spine) and the 8-byte scope
/// header per allocation, nothing else.
#[test]
fn kvcache_scope_agrees_with_memmodel_across_precisions() {
    // a window large enough that per-row bytes dominate container
    // overhead (6 agents x 8 steps would drown in VecDeque spine)
    let sim = SimConfig {
        n_agents: 32,
        history_steps: 32,
        ..SimConfig::default()
    };
    let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
    let scenario = ScenarioGenerator::new(sim.clone()).generate(17);
    let window: Vec<_> = (0..sim.history_steps)
        .map(|t| scenario.states[t].clone())
        .collect();
    assert_eq!(window[0].len(), sim.n_agents, "generator honours n_agents");
    const SESSIONS: u32 = 4;

    for precision in [CachePrecision::F32, CachePrecision::F16] {
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::clone(&stats));
        let before = alloc::snapshot(Scope::KvCache).live_bytes as i64;
        for sample in 0..SESSIONS {
            let key = SessionKey {
                scene: scenario.seed,
                t0: sim.history_steps as u32 - 1,
                sample,
            };
            pool.step_with_precision(key, precision, &tok, &scenario.map_elements, &window)
                .expect("fresh session build");
        }
        let measured = alloc::snapshot(Scope::KvCache).live_bytes as i64 - before;
        let modeled = (SESSIONS as usize
            * memmodel::window_cache_bytes(
                sim.n_agents,
                sim.history_steps,
                tok.feat_dim,
                precision,
            )) as i64;
        assert_eq!(stats.misses.get(), SESSIONS as u64, "all builds must miss");
        let ratio = measured as f64 / modeled as f64;
        assert!(
            (ratio - 1.0).abs() <= 0.10,
            "{precision:?}: measured {measured} B vs modeled {modeled} B \
             (ratio {ratio:.3}) — attribution drifted past 10%"
        );
        // the pool's own byte gauge and the allocator must agree too
        let gauge = stats.resident_bytes.get() as i64;
        assert!(
            gauge <= measured,
            "{precision:?}: telemetry gauge {gauge} exceeds allocator-measured {measured}"
        );
        drop(pool);
        // every session freed: the scope returns to its baseline
        let after = alloc::snapshot(Scope::KvCache).live_bytes as i64;
        assert!(
            (after - before).abs() < modeled / 10,
            "{precision:?}: {} B leaked in the kvcache scope",
            after - before
        );
    }
}

// ---------------------------------------------------------------------------
// the linear-memory claim, measured at the allocator
// ---------------------------------------------------------------------------

type ProblemData = (Vec<f32>, Vec<f32>, Vec<f32>, Vec<Pose>, Vec<i32>);

fn problem_data(n: usize, d: usize, seed: u64) -> ProblemData {
    let mut rng = Rng::new(seed);
    let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let poses: Vec<Pose> = (0..n)
        .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.0, 3.0)))
        .collect();
    let t: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
    (q, k, v, poses, t)
}

/// Run `f` with the calling thread tagged `kernel_scratch` and return
/// the scope's peak rise over its pre-call live bytes — the transient
/// high-water mark of the call.
fn measured_peak(f: impl FnOnce()) -> u64 {
    alloc::reset_peak(Scope::KernelScratch);
    let base = alloc::snapshot(Scope::KernelScratch).live_bytes;
    {
        let _mem = MemScope::enter_scope(Scope::KernelScratch);
        f();
    }
    alloc::snapshot(Scope::KernelScratch)
        .peak_bytes
        .saturating_sub(base)
}

/// The tentpole audit: sweep N with N == M and fit the growth exponent
/// of the *measured* (not modeled) transient peak.  Algorithm 2 must
/// come out linear, Algorithm 1 quadratic — the paper's memory claim
/// reproduced by the process' own allocator.
#[test]
fn measured_attention_peak_is_linear_for_alg2_quadratic_for_alg1() {
    const D: usize = 12;
    let ns = [32usize, 128, 512];
    // single-threaded kernel: every transient lands on this thread, and
    // results are bit-identical at any thread count anyway
    let kcfg = KernelConfig::fixed(64, 8, 1);

    memreport::clear_peak_samples();
    let mut lin_pts = Vec::new();
    let mut quad_pts = Vec::new();
    for &n in &ns {
        let (q, k, v, poses, t) = problem_data(n, D, 23);
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d: D,
            fourier_f: 8,
            scales: &[1.0, 0.5],
            q: &q,
            k: &k,
            v: &v,
            pose_q: &poses,
            pose_k: &poses,
            tq: &t,
            tk: &t,
        };
        let lin = measured_peak(|| {
            linear::attention_with(&p, &kcfg);
        });
        let quad = measured_peak(|| {
            quadratic::attention_with(&p, &kcfg);
        });
        assert!(lin > 0 && quad > 0, "N={n}: peaks must be observable");
        lin_pts.push((n as f64, lin as f64));
        quad_pts.push((n as f64, quad as f64));
        memreport::record_peak_sample(n as u64, lin);
    }

    let lin_exp = memreport::fit_growth_exponent(&lin_pts).expect("linear fit");
    let quad_exp = memreport::fit_growth_exponent(&quad_pts).expect("quadratic fit");
    assert!(
        lin_exp < 1.5,
        "Algorithm 2 measured peak grows as N^{lin_exp:.2} — not linear ({lin_pts:?})"
    );
    assert!(
        quad_exp > 1.7,
        "Algorithm 1 measured peak grows as N^{quad_exp:.2} — \
         expected ~quadratic ({quad_pts:?})"
    );
    // and the same verdict through the recorded-sample audit that the
    // metrics exporter surfaces as se2attn_mem_audit_exponent_centi
    let audit = memreport::audit().expect("three samples recorded");
    assert!(audit.is_linear(), "audit flagged the linear path: {audit:?}");
    assert_eq!(audit.samples, ns.len());
    memreport::clear_peak_samples();
}

// ---------------------------------------------------------------------------
// scope propagation across the executor
// ---------------------------------------------------------------------------

/// Worker threads allocate on behalf of their submitter: both executor
/// flavours (the reusable scoped pool and `par_for`'s fresh threads)
/// must charge worker-side allocations to the scope that was active on
/// the submitting thread.
#[test]
fn executors_charge_worker_allocations_to_the_submitters_scope() {
    const BLOCK: usize = 1 << 20;
    const TASKS: usize = 4;
    let slack = (1 << 20) as i64;
    let keep: Mutex<Vec<Vec<u8>>> = Mutex::new(Vec::new());
    let before = alloc::snapshot(Scope::Trace).live_bytes as i64;
    {
        let _mem = MemScope::enter_scope(Scope::Trace);
        se2attn::exec::par_for(TASKS, 2, |_| {
            keep.lock().unwrap().push(vec![7u8; BLOCK]);
        });
        se2attn::exec::shared_pool().run(TASKS, 3, &|_| {
            keep.lock().unwrap().push(vec![7u8; BLOCK]);
        });
    }
    let held = alloc::snapshot(Scope::Trace).live_bytes as i64 - before;
    let expect = (2 * TASKS * BLOCK) as i64;
    assert!(
        held >= expect && held <= expect + slack,
        "trace scope holds {held} B, expected ~{expect} B — \
         executor workers lost the submitter's scope"
    );
    drop(keep);
    let after = alloc::snapshot(Scope::Trace).live_bytes as i64;
    assert!(
        (after - before).abs() <= slack,
        "frees not credited back to the owning scope ({} B adrift)",
        after - before
    );
}
