//! Integration tests over the full stack: PJRT artifacts vs the native
//! oracles, the training loop, the rollout scheduler, and the server.
//!
//! These require `make artifacts` to have been run; they are skipped (with
//! a loud message) if the artifact directory is missing so `cargo test`
//! stays usable in a fresh checkout.
//!
//! NOTE: the PJRT client is not thread-safe (Rc internals), and tests in
//! one binary may run concurrently — everything PJRT-touching therefore
//! lives in this single #[test] with serialized sections.

use std::sync::Arc;

use se2attn::attention::{quadratic, AttnProblem};
use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{
    AdmissionConfig, ModelHandle, RolloutEngine, RolloutRequest, ServeConfig, Server, Trainer,
};
use se2attn::geometry::Pose;
use se2attn::metrics::TableOneRow;
use se2attn::prng::Rng;
use se2attn::runtime::{Engine, HostTensor};
use se2attn::sim::ScenarioGenerator;

mod common;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/index.json").exists()
}

/// Serving shard count under test (PJRT replicas are per-shard, so the
/// legacy single-shard layout is the default).
fn test_workers() -> usize {
    common::test_workers(1)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn full_stack_integration() {
    if !se2attn::runtime::PJRT_ENABLED {
        eprintln!("SKIPPED: built without the `pjrt` feature (stub runtime)");
        return;
    }
    if !artifacts_available() {
        eprintln!("SKIPPED: run `make artifacts` first");
        return;
    }
    let cfg = SystemConfig::load("artifacts").expect("config");
    let engine = Arc::new(Engine::cpu(&cfg.artifact_dir).expect("engine"));

    attn_artifacts_match_quadratic_oracle(&cfg, &engine);
    flash_artifact_masks_correctly(&engine);
    init_is_deterministic_and_training_reduces_loss(&cfg, &engine);
    decode_respects_temperature(&cfg, &engine);
    rollout_produces_plausible_futures(&cfg, &engine);
    checkpoint_roundtrip_through_model(&cfg, &engine);
    server_end_to_end(&cfg);
    server_shutdown_drains_queued(&cfg);
}

/// Save a trained model's state, restore it into a fresh handle, and check
/// forward outputs agree bit-for-bit.
fn checkpoint_roundtrip_through_model(cfg: &SystemConfig, engine: &Arc<Engine>) {
    let mut model = ModelHandle::init(Arc::clone(engine), Method::Se2Fourier, 9).unwrap();
    let mut trainer = Trainer::new(cfg.model.clone(), cfg.sim.clone(), 24, 2);
    trainer.run(&mut model, 3).unwrap();
    let path = std::env::temp_dir().join("se2attn_it_ck/model.ckpt");
    model
        .to_checkpoint(&cfg.model.param_names)
        .unwrap()
        .save(&path)
        .unwrap();

    let mut restored = ModelHandle::init(Arc::clone(engine), Method::Se2Fourier, 1234).unwrap();
    let ck = se2attn::checkpoint::Checkpoint::load(&path).unwrap();
    restored.restore(&ck, &cfg.model.param_names).unwrap();
    assert_eq!(restored.step, model.step);

    let batch = trainer.loader.next_batch();
    let a = model
        .forward(&batch, cfg.model.n_tokens, cfg.model.feat_dim)
        .unwrap();
    let b = restored
        .forward(&batch, cfg.model.n_tokens, cfg.model.feat_dim)
        .unwrap();
    assert_eq!(a, b, "restored model must be bit-identical");
    let _ = std::fs::remove_file(&path);
    eprintln!("checkpoint roundtrip OK");
}

/// Every per-method AOT attention artifact must match the native quadratic
/// Algorithm 1 (exactly for factorizable methods, to Fourier tolerance for
/// se2fourier) — the cross-language, cross-layer correctness gate.
fn attn_artifacts_match_quadratic_oracle(cfg: &SystemConfig, engine: &Arc<Engine>) {
    let n = cfg.model.n_tokens;
    let dh = cfg.model.head_dim;
    let mut rng = Rng::new(42);
    let q: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let poses: Vec<Pose> = (0..n)
        .map(|_| Pose::new(rng.range(-1.5, 1.5), rng.range(-1.5, 1.5), rng.range(-3.1, 3.1)))
        .collect();
    let pose_flat: Vec<f32> = poses
        .iter()
        .flat_map(|p| [p.x as f32, p.y as f32, p.theta as f32])
        .collect();
    let tq: Vec<i32> = (0..n).map(|i| (i / 8) as i32).collect();

    for (method, tol) in [
        (Method::Rope2d, 2e-4f32),
        (Method::Se2Rep, 2e-4),
        (Method::Se2Fourier, 5e-2),
    ] {
        let artifact = engine
            .load(&format!("attn_{}", method.name()))
            .expect("load attn artifact");
        let out = artifact
            .execute(&[
                HostTensor::f32(vec![n, dh], q.clone()),
                HostTensor::f32(vec![n, dh], k.clone()),
                HostTensor::f32(vec![n, dh], v.clone()),
                HostTensor::f32(vec![n, 3], pose_flat.clone()),
                HostTensor::i32(vec![n], tq.clone()),
            ])
            .expect("execute");
        let got = out[0].as_f32().unwrap();
        let oracle = quadratic::attention(&AttnProblem {
            method,
            d: dh,
            fourier_f: cfg.model.fourier_f,
            scales: &cfg.model.spatial_scales,
            q: &q,
            k: &k,
            v: &v,
            pose_q: &poses,
            pose_k: &poses,
            tq: &tq,
            tk: &tq,
        });
        let err = max_abs_diff(got, &oracle.out);
        assert!(
            err < tol,
            "{}: AOT vs oracle err {err} > {tol}",
            method.name()
        );
        eprintln!("attn_{} vs quadratic oracle: {err:.2e} OK", method.name());
    }
}

/// The standalone flash artifact must honor the tq >= tk visibility rule.
fn flash_artifact_masks_correctly(engine: &Arc<Engine>) {
    let artifact = engine.load("flash_sdpa").expect("flash artifact");
    let n = 256;
    let c = 64;
    let mut rng = Rng::new(7);
    let q: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
    // query 0 sees nothing (t = -10, all keys t = 0)
    let mut tq = vec![5i32; n];
    tq[0] = -10;
    let tk = vec![0i32; n];
    let out = artifact
        .execute(&[
            HostTensor::f32(vec![n, c], q),
            HostTensor::f32(vec![n, c], k),
            HostTensor::f32(vec![n, c], v),
            HostTensor::i32(vec![n], tq),
            HostTensor::i32(vec![n], tk),
        ])
        .expect("execute flash");
    let o = out[0].as_f32().unwrap();
    assert!(
        o[..c].iter().all(|&x| x == 0.0),
        "fully-masked row must be zero"
    );
    assert!(o[c..2 * c].iter().any(|&x| x != 0.0), "visible rows nonzero");
    eprintln!("flash_sdpa masking OK");
}

fn init_is_deterministic_and_training_reduces_loss(cfg: &SystemConfig, engine: &Arc<Engine>) {
    let m1 = ModelHandle::init(Arc::clone(engine), Method::Rope2d, 3).unwrap();
    let m2 = ModelHandle::init(Arc::clone(engine), Method::Rope2d, 3).unwrap();
    for (a, b) in m1.params().iter().zip(m2.params().iter()) {
        assert_eq!(a, b, "init must be deterministic");
    }
    let m3 = ModelHandle::init(Arc::clone(engine), Method::Rope2d, 4).unwrap();
    let diff: f32 = m1
        .params()
        .iter()
        .zip(m3.params().iter())
        .map(|(a, b)| max_abs_diff(a.as_f32().unwrap(), b.as_f32().unwrap()))
        .fold(0.0, f32::max);
    assert!(diff > 1e-3, "different seeds must differ");

    // short training run must reduce loss
    let mut model = m1;
    let mut trainer = Trainer::new(cfg.model.clone(), cfg.sim.clone(), 48, 0);
    let report = trainer.run(&mut model, 12).unwrap();
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(
        last < first,
        "loss must decrease: {first} -> {last}"
    );
    assert!(report.final_val_loss.is_finite(), "val loss finite");
    eprintln!("training: loss {first:.3} -> {last:.3}, val {:.3} OK", report.final_val_loss);
}

fn decode_respects_temperature(cfg: &SystemConfig, engine: &Arc<Engine>) {
    let model = ModelHandle::init(Arc::clone(engine), Method::Se2Fourier, 0).unwrap();
    let mut trainer = Trainer::new(cfg.model.clone(), cfg.sim.clone(), 24, 1);
    let batch = trainer.loader.next_batch();
    let n_tokens = cfg.model.n_tokens;
    let out = model
        .decode(&batch, n_tokens, cfg.model.feat_dim, 11, 1.0)
        .unwrap();
    assert_eq!(out.actions.len(), cfg.model.batch_size * n_tokens);
    assert!(out
        .actions
        .iter()
        .all(|&a| a >= 0 && (a as usize) < cfg.model.n_actions));
    assert!(out.logp.iter().all(|&p| p <= 1e-5));
    // near-greedy sampling equals argmax of returned logits
    let greedy = model
        .decode(&batch, n_tokens, cfg.model.feat_dim, 11, 1e-3)
        .unwrap();
    for i in 0..out.actions.len() {
        let row = &greedy.logits[i * cfg.model.n_actions..(i + 1) * cfg.model.n_actions];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(greedy.actions[i] as usize, argmax, "token {i}");
    }
    eprintln!("decode sampling OK");
}

fn rollout_produces_plausible_futures(cfg: &SystemConfig, engine: &Arc<Engine>) {
    let model = ModelHandle::init(Arc::clone(engine), Method::Se2Fourier, 0).unwrap();
    let rollout = RolloutEngine::new(cfg.model.clone(), cfg.sim.clone());
    let scenario = ScenarioGenerator::new(cfg.sim.clone()).generate(77);
    let req = RolloutRequest {
        scenario,
        t0: cfg.sim.history_steps - 1,
        n_samples: 3,
        temperature: 1.0,
        seed: 5,
    };
    let res = rollout.rollout(&model, &req).unwrap();
    assert_eq!(res.trajectories.len(), 3);
    assert_eq!(res.trajectories[0].len(), cfg.sim.n_agents);
    assert_eq!(res.trajectories[0][0].len(), cfg.sim.future_steps);
    assert_eq!(res.min_ade.len(), cfg.sim.n_agents);
    // kinematic limits: an agent cannot move faster than ~30 m/s
    for sample in &res.trajectories {
        for agent_track in sample {
            for w in agent_track.windows(2) {
                let d = ((w[1].0 - w[0].0).powi(2) + (w[1].1 - w[0].1).powi(2)).sqrt();
                assert!(d < 30.0 * cfg.sim.dt, "teleporting agent: {d} m/step");
            }
        }
    }
    // untrained minADE is finite and bounded by scene scale
    for &ade in &res.min_ade {
        assert!(ade.is_finite() && ade < 200.0);
    }
    // evaluate() aggregates into a Table-I row
    let mut row = TableOneRow::default();
    rollout.evaluate(&model, &[88], 2, &mut row).unwrap();
    assert!(row.nll() > 0.0);
    eprintln!("rollout OK (decode {:.1} ms/step)", res.decode_ms);
}

fn server_end_to_end(cfg: &SystemConfig) {
    let server = Server::start(
        cfg.clone(),
        vec![Method::Rope2d],
        0,
        ServeConfig {
            workers: test_workers(),
            admission: AdmissionConfig {
                max_queue: 16,
                ..AdmissionConfig::default()
            },
            ..ServeConfig::default()
        },
    )
    .expect("server start");
    assert_eq!(server.n_shards(), test_workers());
    let gen = ScenarioGenerator::new(cfg.sim.clone());
    let mut pending = Vec::new();
    for i in 0..3 {
        pending.push(server.submit(
            Method::Rope2d,
            RolloutRequest {
                scenario: gen.generate(300 + i),
                t0: cfg.sim.history_steps - 1,
                n_samples: 2,
                temperature: 1.0,
                seed: i as i32,
            },
        ));
    }
    for rx in pending {
        let res = rx.recv().expect("alive").expect("rollout ok");
        assert_eq!(res.min_ade.len(), cfg.sim.n_agents);
    }
    // unknown method is rejected, not wedged
    let rx = server.submit(
        Method::Abs,
        RolloutRequest {
            scenario: gen.generate(999),
            t0: cfg.sim.history_steps - 1,
            n_samples: 1,
            temperature: 1.0,
            seed: 0,
        },
    );
    // Abs was not deployed: the server only accepts deployed methods;
    // undeployed ones error instead of wedging the shard worker.
    match rx.recv() {
        Ok(Err(_)) | Err(_) => {}
        Ok(Ok(_)) => panic!("undeployed method must not succeed"),
    }
    assert_eq!(server.stats.requests_done.get(), 3);
    // per-family counters appear in the stats line (corridor traffic),
    // and so does the per-shard breakdown block
    let summary = server.stats.summary();
    assert!(summary.contains("corridor:req=3"), "{summary}");
    assert!(summary.contains("shards[s0:"), "{summary}");
    eprintln!("server OK: {summary}");
}

/// Regression: requests still waiting in the admission queue at shutdown
/// must drain through the rollout engine (real results), not be dropped
/// or answered with a shutdown error.
fn server_shutdown_drains_queued(cfg: &SystemConfig) {
    let stats = {
        // admission pacing that can never fire on its own: the queue
        // holds everything until the shutdown drain
        let server = Server::start(
            cfg.clone(),
            vec![Method::Rope2d],
            0,
            ServeConfig {
                workers: test_workers(),
                admission: AdmissionConfig {
                    max_queue: 64,
                    tenant_rate: 1e-9,
                    tenant_burst: 0.0,
                    ..AdmissionConfig::default()
                },
                ..ServeConfig::default()
            },
        )
        .expect("server start");
        let gen = ScenarioGenerator::new(cfg.sim.clone());
        let mut pending = Vec::new();
        for i in 0..2u64 {
            pending.push(server.submit(
                Method::Rope2d,
                RolloutRequest {
                    scenario: gen.generate(700 + i),
                    t0: cfg.sim.history_steps - 1,
                    n_samples: 2,
                    temperature: 1.0,
                    seed: i as i32,
                },
            ));
        }
        let stats = std::sync::Arc::clone(&server.stats);
        drop(server); // shutdown with everything still queued for admission
        for rx in pending {
            let res = rx
                .recv()
                .expect("queued caller must get a response")
                .expect("drained request must produce a real rollout");
            assert_eq!(res.min_ade.len(), cfg.sim.n_agents);
            assert_eq!(res.trajectories.len(), 2);
        }
        stats
    };
    assert_eq!(stats.requests_done.get(), 2, "both drained through rollout");
    assert_eq!(stats.requests_failed.get(), 0);
    eprintln!("shutdown drain OK: {}", stats.summary());
}
