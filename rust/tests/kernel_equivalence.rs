//! Blocked-kernel equivalence suite (ISSUE 4): the blocked multithreaded
//! flash kernel must agree with the scalar oracle within 1e-5 on every
//! `Method`, under ragged timestamp masks and random SE(2) re-anchors,
//! and must be **bit-identical across thread counts** for a fixed
//! `block_m` — so results never depend on the serving host's core count.
//!
//! Runs in the default stub build (no artifacts, no XLA).

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::kernel::{flash_sdpa_blocked, flash_sdpa_scalar, KernelConfig};
use se2attn::attention::{linear, quadratic, AttnProblem};
use se2attn::config::Method;
use se2attn::geometry::Pose;
use se2attn::prng::Rng;

const METHODS: [(Method, usize); 4] = [
    (Method::Abs, 8),
    (Method::Rope2d, 8),
    (Method::Se2Rep, 9),
    (Method::Se2Fourier, 12),
];

struct ProblemData {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    pq: Vec<Pose>,
    pk: Vec<Pose>,
    tq: Vec<i32>,
    tk: Vec<i32>,
}

/// Random problem with a deliberately ragged visibility mask: timestamps
/// span a wide range, a few query rows precede every key (all-masked),
/// and a few keys are in the future of every query.
fn ragged_data(rng: &mut Rng, n: usize, m: usize, d: usize) -> ProblemData {
    let gen = |rng: &mut Rng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    };
    let pose = |rng: &mut Rng| {
        Pose::new(rng.range(-1.5, 1.5), rng.range(-1.5, 1.5), rng.range(-3.1, 3.1))
    };
    let mut tq: Vec<i32> = (0..n).map(|_| rng.int_range(0, 6) as i32).collect();
    let mut tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, 6) as i32).collect();
    tq[0] = -100; // all-masked query row (must be a zero row, not NaN)
    if n > 1 {
        tq[n - 1] = 100; // fully visible query row
    }
    tk[m - 1] = 50; // key invisible to every normal query
    ProblemData {
        q: gen(rng, n * d),
        k: gen(rng, m * d),
        v: gen(rng, m * d),
        pq: (0..n).map(|_| pose(rng)).collect(),
        pk: (0..m).map(|_| pose(rng)).collect(),
        tq,
        tk,
    }
}

fn problem<'a>(
    method: Method,
    d: usize,
    data: &'a ProblemData,
    scales: &'a [f64],
) -> AttnProblem<'a> {
    AttnProblem {
        method,
        d,
        fourier_f: 16,
        scales,
        q: &data.q,
        k: &data.k,
        v: &data.v,
        pose_q: &data.pq,
        pose_k: &data.pk,
        tq: &data.tq,
        tk: &data.tk,
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(x.is_finite() && y.is_finite(), "{what} [{i}]: {x} vs {y}");
        assert!((x - y).abs() < tol, "{what} [{i}]: {x} vs {y}");
    }
}

/// Blocked kernel vs scalar oracle, end to end through Algorithm 2, for
/// every method and a sweep of (ragged) block sizes and thread counts.
#[test]
fn blocked_matches_scalar_all_methods() {
    let scales = [1.0, 0.5];
    let mut rng = Rng::new(2024);
    for (method, d) in METHODS {
        let data = ragged_data(&mut rng, 13, 29, d);
        let p = problem(method, d, &data, &scales);
        let want = linear::attention_ref(&p).out;
        assert!(want.iter().all(|x| x.is_finite()), "{method:?}: oracle finite");
        for block_m in [1usize, 7, 64] {
            for threads in [1usize, 4] {
                let got = linear::attention_with(&p, &KernelConfig::fixed(block_m, 8, threads)).out;
                assert_close(
                    &want,
                    &got,
                    1e-5,
                    &format!("{method:?} block_m={block_m} threads={threads}"),
                );
            }
        }
    }
}

/// For a fixed block_m the blocked kernel is bit-identical across thread
/// counts — at the raw kernel level and through Algorithm 2.
#[test]
fn thread_counts_are_bit_identical() {
    let scales = [1.0, 0.5, 0.25];
    let mut rng = Rng::new(7);
    for (method, d) in METHODS {
        let data = ragged_data(&mut rng, 21, 43, d);
        let p = problem(method, d, &data, &scales);
        let one = linear::attention_with(&p, &KernelConfig::fixed(16, 8, 1)).out;
        let four = linear::attention_with(&p, &KernelConfig::fixed(16, 8, 4)).out;
        assert_eq!(one, four, "{method:?}: attention bit-identity");
    }
    // raw kernel on unprojected tensors
    let d = 24;
    let data = ragged_data(&mut rng, 33, 57, d);
    let scale = 1.0 / (d as f64).sqrt();
    let mut one = vec![0.0f32; 33 * d];
    let mut four = vec![0.0f32; 33 * d];
    flash_sdpa_blocked(
        &data.q, &data.k, &data.v, &data.tq, &data.tk, d, scale, &mut one,
        &KernelConfig::fixed(8, 8, 1),
    );
    flash_sdpa_blocked(
        &data.q, &data.k, &data.v, &data.tq, &data.tk, d, scale, &mut four,
        &KernelConfig::fixed(8, 8, 4),
    );
    assert_eq!(one, four, "raw kernel bit-identity");
}

/// Pinned all-masked behavior (ISSUE 4 bugfix): a query row whose
/// timestamp precedes every key is a defined zero row in BOTH kernels —
/// never a `0/0 = NaN` row.
#[test]
fn all_masked_query_rows_are_zero_in_both_kernels() {
    let mut rng = Rng::new(55);
    let (n, m, c) = (6usize, 11usize, 18usize);
    let q: Vec<f32> = (0..n * c).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..m * c).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..m * c).map(|_| rng.normal() as f32).collect();
    let tq = vec![-1i32; n]; // every query precedes every key
    let tk: Vec<i32> = (0..m as i32).collect();
    let scale = 1.0 / (c as f64).sqrt();

    let mut scalar = vec![f32::NAN; n * c];
    flash_sdpa_scalar(&q, &k, &v, &tq, &tk, c, scale, &mut scalar);
    assert!(scalar.iter().all(|&x| x == 0.0), "scalar kernel: zero, not NaN");

    let mut blocked = vec![f32::NAN; n * c];
    flash_sdpa_blocked(
        &q, &k, &v, &tq, &tk, c, scale, &mut blocked,
        &KernelConfig::fixed(4, 8, 2),
    );
    assert!(blocked.iter().all(|&x| x == 0.0), "blocked kernel: zero, not NaN");

    // mixed: one visible key only for the last query
    let mut tq2 = tq.clone();
    tq2[n - 1] = 0;
    let mut out = vec![f32::NAN; n * c];
    flash_sdpa_blocked(
        &q, &k, &v, &tq2, &tk, c, scale, &mut out,
        &KernelConfig::fixed(4, 8, 2),
    );
    assert!(out[..(n - 1) * c].iter().all(|&x| x == 0.0));
    assert!(out[(n - 1) * c..].iter().all(|x| x.is_finite()));
    // the visible row attends exactly one key (tk == 0): output == v_0
    for (o, &vv) in out[(n - 1) * c..].iter().zip(v[..c].iter()) {
        assert!((o - vv).abs() < 1e-6);
    }
}

/// The incremental decode engine's cached-row attend runs on the blocked
/// kernel: after random SE(2) re-anchors it must still agree with the
/// scalar-oracle Algorithm 2 on the shifted poses, and stay bit-identical
/// across thread counts.
#[test]
fn re_anchored_cache_attend_matches_oracle() {
    let scales = vec![1.0, 0.5];
    let mut rng = Rng::new(31);
    for trial in 0..5 {
        let (d, f, n, m) = (12usize, 24usize, 5usize, 17usize);
        let data = ragged_data(&mut rng, n, m, d);
        let g = Pose::new(rng.range(-0.8, 0.8), rng.range(-0.8, 0.8), rng.range(-3.1, 3.1));

        let mk_engine = |threads: usize| {
            let mut eng = IncrementalAttention::new(IncrementalConfig {
                method: Method::Se2Fourier,
                d,
                fourier_f: f,
                scales: scales.clone(),
                kernel: KernelConfig::fixed(8, 8, threads),
                precision: se2attn::config::CachePrecision::F32,
            });
            eng.append(&data.k, &data.v, &data.pk, &data.tk);
            eng.re_anchor(&g).expect("se2fourier re-anchor");
            eng
        };
        let pq_shifted: Vec<Pose> = data.pq.iter().map(|p| g.compose(p)).collect();
        let got = mk_engine(4).attend(&data.q, &pq_shifted, &data.tq).out;

        // oracle: fresh Algorithm 2 over the scalar kernel at the
        // shifted poses (re-anchor exactness is F-limited; F=24 at
        // |p| <= ~2 keeps it below the 1e-5 equivalence budget)
        let pk_shifted: Vec<Pose> = data.pk.iter().map(|p| g.compose(p)).collect();
        let want = linear::attention_ref(&AttnProblem {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: &scales,
            q: &data.q,
            k: &data.k,
            v: &data.v,
            pose_q: &pq_shifted,
            pose_k: &pk_shifted,
            tq: &data.tq,
            tk: &data.tk,
        })
        .out;
        assert_close(&want, &got, 1e-4, &format!("re-anchor trial {trial}"));

        // thread count must not change a single bit
        let one = mk_engine(1).attend(&data.q, &pq_shifted, &data.tq).out;
        assert_eq!(one, got, "re-anchored attend bit-identity (trial {trial})");
    }
}

/// The quadratic oracle's row partition is also bit-stable across thread
/// counts and unchanged vs the linear path's agreement bound.
#[test]
fn quadratic_row_partition_is_bit_identical() {
    let scales = [1.0, 0.5];
    let mut rng = Rng::new(91);
    for (method, d) in METHODS {
        let data = ragged_data(&mut rng, 9, 15, d);
        let p = problem(method, d, &data, &scales);
        let one = quadratic::attention_with(&p, &KernelConfig::fixed(64, 8, 1)).out;
        let four = quadratic::attention_with(&p, &KernelConfig::fixed(64, 8, 4)).out;
        assert_eq!(one, four, "{method:?}: quadratic bit-identity");
        assert!(one.iter().all(|x| x.is_finite()), "{method:?}: finite");
    }
}
