//! Acceptance suite for the quantized KV feature cache (DESIGN.md §14):
//!
//! * cached-quantized attend vs full f32 recompute stays within 1e-2
//!   (f16) across random SE(2) re-anchors, and the f32 cached path stays
//!   within 1e-5;
//! * f16 resident bytes are <= 60% of f32 for the same rows, and every
//!   `resident_bytes()` gauge equals the closed-form
//!   [`se2attn::attention::memmodel`] byte model (one source of truth);
//! * a mixed f32/f16 session population under a tight byte budget evicts
//!   strictly in LRU order priced by true bytes, and every surviving
//!   session still round-trips `step`/`emit`;
//! * cache hit/miss/eviction counters are identical across precisions
//!   for the same workload.

use std::sync::Arc;

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::memmodel::{
    incremental_cache_bytes, map_tokens_bytes, window_cache_bytes,
};
use se2attn::attention::{linear, AttnProblem};
use se2attn::config::{CachePrecision, Method, ModelConfig, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::prng::Rng;
use se2attn::proplite::check;
use se2attn::sim::{AgentState, ScenarioGenerator};
use se2attn::tokenizer::Tokenizer;

const D: usize = 12;
const F: usize = 24;

fn rand_pose(rng: &mut Rng, r: f64) -> Pose {
    Pose::new(rng.range(-r, r), rng.range(-r, r), rng.range(-3.1, 3.1))
}

/// Build a cached engine at `precision`, apply `n_reanchors` random
/// SE(2) re-anchors, and return the max abs error of its attend output
/// against a full f32 recompute (Algorithm 2 from the raw k/v at the
/// current — exactly tracked — poses).
fn attend_error_vs_full_recompute(
    precision: CachePrecision,
    n_reanchors: usize,
    rng: &mut Rng,
) -> f32 {
    let scales = vec![1.0, 0.5];
    let (n, m) = (4usize, 14usize);
    let q: Vec<f32> = (0..n * D).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..m * D).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..m * D).map(|_| rng.normal() as f32).collect();
    let pk: Vec<Pose> = (0..m).map(|_| rand_pose(rng, 1.0)).collect();
    let pq: Vec<Pose> = (0..n).map(|_| rand_pose(rng, 1.0)).collect();
    let tk: Vec<i32> = (0..m).map(|_| rng.int_range(0, 3) as i32).collect();
    let tq = vec![5i32; n];

    let mut eng = IncrementalAttention::new(IncrementalConfig {
        method: Method::Se2Fourier,
        d: D,
        fourier_f: F,
        scales: scales.clone(),
        kernel: KernelConfig::default(),
        precision,
    });
    eng.append(&k, &v, &pk, &tk);

    // poses tracked exactly on the test side, mirroring the engine's
    // own (f64-exact) pose bookkeeping
    let mut cur_k = pk;
    let mut cur_q = pq;
    for _ in 0..n_reanchors {
        let g = rand_pose(rng, 0.35);
        eng.re_anchor(&g).expect("se2fourier re-anchor");
        cur_k = cur_k.iter().map(|p| g.compose(p)).collect();
        cur_q = cur_q.iter().map(|p| g.compose(p)).collect();
    }

    let got = eng.attend(&q, &cur_q, &tq).out;
    let want = linear::attention(&AttnProblem {
        method: Method::Se2Fourier,
        d: D,
        fourier_f: F,
        scales: &scales,
        q: &q,
        k: &k,
        v: &v,
        pose_q: &cur_q,
        pose_k: &cur_k,
        tq: &tq,
        tk: &tk,
    })
    .out;
    want.iter()
        .zip(got.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max)
}

/// Acceptance: f16 cached + re-anchored vs full recompute <= 1e-2.
#[test]
fn f16_cached_attend_within_1e2_of_full_recompute() {
    check("f16 cached attend vs full recompute", 6, |rng| {
        let err = attend_error_vs_full_recompute(CachePrecision::F16, 2, rng);
        if err <= 1e-2 {
            Ok(())
        } else {
            Err(format!("f16 max abs error {err} > 1e-2"))
        }
    });
}

/// Acceptance: the f32 cached path stays at 1e-5 under re-anchoring.
#[test]
fn f32_cached_attend_within_1e5_of_full_recompute() {
    check("f32 cached attend vs full recompute", 6, |rng| {
        let err = attend_error_vs_full_recompute(CachePrecision::F32, 1, rng);
        if err <= 1e-5 {
            Ok(())
        } else {
            Err(format!("f32 max abs error {err} > 1e-5"))
        }
    });
}

/// bf16 trades ~8x the rounding of f16 for the same bytes; it must stay
/// within its own (wider) band.
#[test]
fn bf16_cached_attend_stays_bounded() {
    check("bf16 cached attend vs full recompute", 4, |rng| {
        let err = attend_error_vs_full_recompute(CachePrecision::Bf16, 2, rng);
        if err <= 6e-2 {
            Ok(())
        } else {
            Err(format!("bf16 max abs error {err} > 6e-2"))
        }
    });
}

/// Re-anchors that compose back to the identity leave a quantized cache
/// within a few storage roundings of the untouched f32 cache: error
/// grows additively with the number of re-anchors, never compounds.
#[test]
fn repeated_re_anchors_do_not_compound_quantization_error() {
    let mut rng = Rng::new(4711);
    let scales = vec![1.0, 0.5];
    let (n, m) = (4usize, 10usize);
    let q: Vec<f32> = (0..n * D).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..m * D).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..m * D).map(|_| rng.normal() as f32).collect();
    let pk: Vec<Pose> = (0..m).map(|_| rand_pose(&mut rng, 1.0)).collect();
    let pq: Vec<Pose> = (0..n).map(|_| rand_pose(&mut rng, 1.0)).collect();
    let tk = vec![0i32; m];
    let tq = vec![5i32; n];
    let build = |precision: CachePrecision| {
        let mut eng = IncrementalAttention::new(IncrementalConfig {
            method: Method::Se2Fourier,
            d: D,
            fourier_f: F,
            scales: scales.clone(),
            kernel: KernelConfig::default(),
            precision,
        });
        eng.append(&k, &v, &pk, &tk);
        eng
    };
    let exact = build(CachePrecision::F32);
    let mut eng = build(CachePrecision::F16);
    // 4 round trips = 8 re-anchors composing to the identity
    for _ in 0..4 {
        let g = rand_pose(&mut rng, 0.3);
        eng.re_anchor(&g).unwrap();
        eng.re_anchor(&g.inverse()).unwrap();
    }
    let want = exact.attend(&q, &pq, &tq).out;
    let got = eng.attend(&q, &pq, &tq).out;
    for (i, (a, b)) in want.iter().zip(got.iter()).enumerate() {
        assert!(
            (a - b).abs() < 1e-2,
            "[{i}] after 8 re-anchors: {a} vs {b} — quantization error compounded"
        );
    }
}

/// Acceptance: f16 resident bytes <= 60% of f32 for the same rows, and
/// both match the closed-form memmodel — the single byte model the
/// telemetry gauge reports.
#[test]
fn f16_resident_bytes_le_60_percent_and_match_memmodel() {
    let mut rng = Rng::new(99);
    let (d, f, m) = (48usize, 12usize, 256usize);
    let k: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
    let poses: Vec<Pose> = (0..m).map(|_| rand_pose(&mut rng, 1.0)).collect();
    let t = vec![0i32; m];
    let bytes_at = |precision: CachePrecision| {
        let mut eng = IncrementalAttention::new(IncrementalConfig {
            method: Method::Se2Fourier,
            d,
            fourier_f: f,
            scales: vec![1.0, 0.5, 0.25, 0.125],
            kernel: KernelConfig::default(),
            precision,
        });
        eng.append(&k, &k, &poses, &t);
        let got = eng.resident_bytes();
        assert_eq!(
            got,
            incremental_cache_bytes(Method::Se2Fourier, m, d, f, precision),
            "{precision:?}: engine accounting must equal the memmodel"
        );
        got
    };
    let f32_bytes = bytes_at(CachePrecision::F32);
    let f16_bytes = bytes_at(CachePrecision::F16);
    let ratio = f16_bytes as f64 / f32_bytes as f64;
    assert!(ratio <= 0.60, "f16/f32 resident ratio {ratio} > 60%");
}

fn setup() -> (SimConfig, Tokenizer) {
    let sim = SimConfig::default();
    let tok = Tokenizer::new(&ModelConfig::synthetic(), &sim);
    (sim, tok)
}

fn slide(window: &mut Vec<Vec<AgentState>>, next: &[AgentState]) {
    window.remove(0);
    window.push(next.to_vec());
}

/// Satellite fix regression: the shared resident-bytes gauge equals the
/// memmodel closed form for quantized sessions (true stored bytes, not
/// the f32-equivalent).
#[test]
fn telemetry_gauge_prices_quantized_sessions_with_the_memmodel() {
    let (sim, tok) = setup();
    let s = ScenarioGenerator::new(sim.clone()).generate(61);
    let h = sim.history_steps;
    let window: Vec<Vec<AgentState>> = (0..h).map(|t| s.states[t].clone()).collect();
    for precision in [CachePrecision::F16, CachePrecision::Bf16, CachePrecision::F32] {
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(
            CacheConfig {
                precision,
                ..CacheConfig::default()
            },
            Arc::clone(&stats),
        );
        let key = SessionKey { scene: 61, t0: 7, sample: 0 };
        pool.step(key, &tok, &s.map_elements, &window).unwrap();
        let want = window_cache_bytes(sim.n_agents, h, tok.feat_dim, precision)
            + map_tokens_bytes(s.map_elements.len(), tok.feat_dim);
        assert_eq!(
            stats.resident_bytes.get() as usize,
            want,
            "{precision:?}: gauge must equal memmodel session + map bytes"
        );
    }
}

/// Satellite: hit/miss/eviction counters are a pure function of the
/// workload — identical at every storage precision.
#[test]
fn cache_counters_agree_across_precisions() {
    let (sim, tok) = setup();
    let s = ScenarioGenerator::new(sim.clone()).generate(71);
    let h = sim.history_steps;
    let run = |precision: CachePrecision| -> (u64, u64, u64) {
        let stats = Arc::new(CacheStats::default());
        let pool = KvCachePool::new(
            CacheConfig {
                precision,
                max_sessions: 2, // force evictions
                ..CacheConfig::default()
            },
            Arc::clone(&stats),
        );
        let mut window: Vec<Vec<AgentState>> =
            (0..h).map(|t| s.states[t].clone()).collect();
        for t in h..h + 3 {
            for sample in 0..3u32 {
                pool.step(
                    SessionKey { scene: 71, t0: 7, sample },
                    &tok,
                    &s.map_elements,
                    &window,
                )
                .unwrap();
            }
            slide(&mut window, &s.states[t]);
        }
        (stats.hits.get(), stats.misses.get(), stats.evictions.get())
    };
    let f32_counts = run(CachePrecision::F32);
    let f16_counts = run(CachePrecision::F16);
    let bf16_counts = run(CachePrecision::Bf16);
    assert_eq!(f32_counts, f16_counts, "f16 counters diverged from f32");
    assert_eq!(f32_counts, bf16_counts, "bf16 counters diverged from f32");
    assert!(f32_counts.2 > 0, "workload must actually evict");
}

/// Satellite property test: under a tight byte budget a mixed f32/f16
/// population evicts strictly in LRU order priced by true bytes, and
/// every surviving session still round-trips step/emit correctly.
#[test]
fn mixed_precision_eviction_is_lru_by_true_bytes() {
    let (sim, tok) = setup();
    let s = ScenarioGenerator::new(sim.clone()).generate(83);
    let h = sim.history_steps;
    let window: Vec<Vec<AgentState>> = (0..h).map(|t| s.states[t].clone()).collect();

    let f32_bytes = window_cache_bytes(sim.n_agents, h, tok.feat_dim, CachePrecision::F32);
    let f16_bytes = window_cache_bytes(sim.n_agents, h, tok.feat_dim, CachePrecision::F16);
    assert!(f16_bytes < f32_bytes);

    // budget fits the last two f16 sessions plus one f32 — computed from
    // the same byte model the pool enforces
    let precisions = [
        CachePrecision::F32, // s0
        CachePrecision::F16, // s1
        CachePrecision::F32, // s2
        CachePrecision::F16, // s3
        CachePrecision::F16, // s4
    ];
    let bytes_of = |p: CachePrecision| match p {
        CachePrecision::F32 => f32_bytes,
        _ => f16_bytes,
    };
    let budget = f32_bytes + 2 * f16_bytes;
    let stats = Arc::new(CacheStats::default());
    let pool = KvCachePool::new(
        CacheConfig {
            max_bytes: budget,
            ..CacheConfig::default()
        },
        Arc::clone(&stats),
    );
    let key = |sample: u32| SessionKey { scene: 83, t0: 7, sample };
    for (i, &p) in precisions.iter().enumerate() {
        pool.step_with_precision(key(i as u32), p, &tok, &s.map_elements, &window)
            .unwrap();
    }

    // simulate LRU-by-bytes over the insertion order: evict oldest until
    // the total fits the budget
    let mut survivors: Vec<usize> = (0..precisions.len()).collect();
    let mut total: usize = precisions.iter().map(|&p| bytes_of(p)).sum();
    while total > budget {
        let evicted = survivors.remove(0);
        total -= bytes_of(precisions[evicted]);
    }
    assert_eq!(
        stats.evictions.get() as usize,
        precisions.len() - survivors.len(),
        "eviction count must match the byte-model simulation"
    );
    assert_eq!(pool.live_sessions(), survivors.len());
    assert_eq!(
        pool.session_bytes(),
        total,
        "pool session bytes must equal the survivors' true byte sum"
    );

    // every surviving session round-trips step/emit: stepping it is a
    // HIT (proving which sessions survived — strict LRU order), and the
    // emitted scene matches a full re-tokenization of the slid window
    let mut next = window.clone();
    slide(&mut next, &s.states[h]);
    let want = tok.tokenize_window(&s.map_elements, &next, None);
    for &i in &survivors {
        let hits_before = stats.hits.get();
        let got = pool
            .step_with_precision(key(i as u32), precisions[i], &tok, &s.map_elements, &next)
            .unwrap();
        assert_eq!(
            stats.hits.get(),
            hits_before + 1,
            "survivor s{i} must hit — LRU evicted the wrong session"
        );
        assert_eq!(got.pose, want.pose, "s{i}: poses exact at every precision");
        assert_eq!(got.tq, want.tq);
        if precisions[i] == CachePrecision::F32 {
            assert_eq!(got.feat, want.feat, "s{i}: f32 emit is bit-identical");
        } else {
            for (a, b) in got.feat.iter().zip(want.feat.iter()) {
                assert!((a - b).abs() < 5e-2, "s{i}: {a} vs {b}");
            }
        }
    }
    // and the evicted sessions are gone: stepping one is a miss
    if survivors.len() < precisions.len() {
        let gone = 0u32;
        let misses_before = stats.misses.get();
        pool.step_with_precision(
            key(gone),
            precisions[0],
            &tok,
            &s.map_elements,
            &next,
        )
        .unwrap();
        assert_eq!(stats.misses.get(), misses_before + 1, "evicted session must miss");
    }
}
