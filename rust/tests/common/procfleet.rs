//! Process-orchestration helpers for the multi-process serving tests
//! (ISSUE 10): spawn real worker processes from the built
//! `se2-attention` binary, kill them mid-rollout, and interpose a
//! chaos proxy on the worker socket to inject delay and partitions.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Path of the `se2-attention` binary Cargo built for this test run.
pub fn worker_bin() -> &'static str {
    env!("CARGO_BIN_EXE_se2-attention")
}

/// argv prefix for a synthetic worker fleet: the hidden `worker` entry
/// point serving `method` with `work` spin-iterations per token
/// (0 = the native flash kernel — bit-identical to the in-process
/// synthetic server).
pub fn synthetic_worker_cmd(method: &str, work: usize) -> Vec<String> {
    vec![
        worker_bin().to_string(),
        "worker".to_string(),
        "--methods".to_string(),
        method.to_string(),
        "--synthetic-work".to_string(),
        work.to_string(),
    ]
}

/// SIGKILL by pid — the worker gets no chance to flush, drain, or say
/// goodbye.  Uses the `kill` binary so the test suite needs no libc
/// binding.
pub fn sigkill(pid: u32) {
    let _ = std::process::Command::new("kill")
        .arg("-9")
        .arg(pid.to_string())
        .status();
}

/// A TCP relay with injectable faults, sitting between a worker and the
/// coordinator (`ProcServer::spawn_worker_via` points a worker here):
///
/// * `set_delay_ms` — added latency per relayed chunk, both directions;
/// * `pause` / `resume` — a partition: connections stay open but no
///   bytes flow, so heartbeats stop and the coordinator's `death_after`
///   liveness sweep is what notices, not a socket error.
pub struct ChaosProxy {
    addr: SocketAddr,
    delay_ms: Arc<AtomicU64>,
    paused: Arc<AtomicBool>,
    shutdown: Arc<AtomicBool>,
}

impl ChaosProxy {
    pub fn start(target: SocketAddr) -> std::io::Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let delay_ms = Arc::new(AtomicU64::new(0));
        let paused = Arc::new(AtomicBool::new(false));
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let delay = Arc::clone(&delay_ms);
            let paused = Arc::clone(&paused);
            let shutdown = Arc::clone(&shutdown);
            thread::spawn(move || {
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(client) = conn else { continue };
                    let Ok(upstream) = TcpStream::connect(target) else { continue };
                    pump_pair(client, upstream, &delay, &paused, &shutdown);
                }
            });
        }
        Ok(ChaosProxy {
            addr,
            delay_ms,
            paused,
            shutdown,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn set_delay_ms(&self, ms: u64) {
        self.delay_ms.store(ms, Ordering::SeqCst);
    }

    pub fn pause(&self) {
        self.paused.store(true, Ordering::SeqCst);
    }

    pub fn resume(&self) {
        self.paused.store(false, Ordering::SeqCst);
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.addr);
    }
}

fn pump_pair(
    a: TcpStream,
    b: TcpStream,
    delay: &Arc<AtomicU64>,
    paused: &Arc<AtomicBool>,
    shutdown: &Arc<AtomicBool>,
) {
    let (a2, b2) = match (a.try_clone(), b.try_clone()) {
        (Ok(a2), Ok(b2)) => (a2, b2),
        _ => return,
    };
    let (d1, p1, s1) = (Arc::clone(delay), Arc::clone(paused), Arc::clone(shutdown));
    thread::spawn(move || pump(a, b, &d1, &p1, &s1));
    let (d2, p2, s2) = (Arc::clone(delay), Arc::clone(paused), Arc::clone(shutdown));
    thread::spawn(move || pump(b2, a2, &d2, &p2, &s2));
}

fn pump(
    mut from: TcpStream,
    mut to: TcpStream,
    delay: &AtomicU64,
    paused: &AtomicBool,
    shutdown: &AtomicBool,
) {
    let mut buf = [0u8; 4096];
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        while paused.load(Ordering::SeqCst) && !shutdown.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(5));
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let d = delay.load(Ordering::SeqCst);
        if d > 0 {
            thread::sleep(Duration::from_millis(d));
        }
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
