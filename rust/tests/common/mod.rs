//! Helpers shared by the integration test binaries.

// not every test binary that mounts `common` drives worker processes
#[allow(dead_code)]
pub mod procfleet;

/// Worker-shard count for server tests, threaded through the environment
/// so CI exercises both the single-shard and the multi-shard serving
/// path (`SE2ATTN_TEST_WORKERS=1` / `=4`) on every push.  `default`
/// applies when the variable is unset or unparsable.
pub fn test_workers(default: usize) -> usize {
    std::env::var("SE2ATTN_TEST_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
