//! SE(2) invariance across the whole scenario suite: for every registered
//! family, applying a random global rigid transform to the generated world
//! must leave the tokenized frame-invariant features bit-identical (well
//! within the 1e-9 gate) and the robot-frame poses unchanged up to f32
//! rounding.  This is the paper's core claim — viewpoint generalization
//! without augmentation — exercised against every world geometry we can
//! generate, not just the legacy corridor.

use se2attn::config::{ModelConfig, SimConfig};
use se2attn::geometry::{wrap_angle, Pose};
use se2attn::proplite::check;
use se2attn::sim::suite::{registry, FamilyId, MixGenerator, WorkloadMix};
use se2attn::sim::Scenario;
use se2attn::tokenizer::Tokenizer;

fn test_model_config() -> ModelConfig {
    ModelConfig::synthetic()
}

/// Apply a rigid transform to every pose a scenario carries.
fn transform_scenario(s: &Scenario, z: &Pose) -> Scenario {
    let mut out = s.clone();
    for step in out.states.iter_mut() {
        for a in step.iter_mut() {
            a.pose = z.compose(&a.pose);
        }
    }
    for e in out.map_elements.iter_mut() {
        e.pose = z.compose(&e.pose);
    }
    out
}

#[test]
fn tokenized_features_invariant_across_all_families() {
    let sim = SimConfig::default();
    let tok = Tokenizer::new(&test_model_config(), &sim);
    for fam in registry() {
        check(&format!("SE(2) invariance [{}]", fam.id.name()), 6, |rng| {
            let seed = rng.next_u64() % 4096;
            let s = fam.generate(&sim, seed);
            let z = Pose::new(
                rng.range(-300.0, 300.0),
                rng.range(-300.0, 300.0),
                rng.range(-std::f64::consts::PI, std::f64::consts::PI),
            );
            let s2 = transform_scenario(&s, &z);
            let t0 = sim.history_steps - 1;
            let a = tok.tokenize_scenario(&s, t0);
            let b = tok.tokenize_scenario(&s2, t0);

            // frame-invariant features: the acceptance gate is 1e-9 (they
            // are bit-identical by construction — any drift means absolute
            // coordinates leaked into a feature channel)
            for (i, (x, y)) in a.feat.iter().zip(b.feat.iter()).enumerate() {
                if (x - y).abs() > 1e-9 {
                    return Err(format!(
                        "family {} seed {seed}: feat[{i}] {x} vs {y}",
                        fam.id.name()
                    ));
                }
            }
            // targets and visibility timesteps are geometry-free
            if a.target != b.target || a.tq != b.tq {
                return Err(format!("family {} seed {seed}: targets/tq drifted", fam.id.name()));
            }
            // robot-frame poses agree up to f32 rounding of the transform
            for (i, (x, y)) in a.pose.iter().zip(b.pose.iter()).enumerate() {
                let d = if i % 3 == 2 {
                    wrap_angle((x - y) as f64).abs()
                } else {
                    (x - y).abs() as f64
                };
                if d > 1e-4 {
                    return Err(format!(
                        "family {} seed {seed}: pose[{i}] {x} vs {y}",
                        fam.id.name()
                    ));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn relative_geometry_preserved_in_f64_for_mixed_workloads() {
    // the same property checked upstream of the tokenizer in full f64:
    // pairwise relative poses between agents are rigid-transform invariant
    // for every scenario a mixed workload can produce
    let sim = SimConfig::default();
    let ids: Vec<FamilyId> = registry().iter().map(|f| f.id).collect();
    let gen = MixGenerator::new(sim.clone(), WorkloadMix::uniform(&ids));
    check("mixed-workload relative geometry", 24, |rng| {
        let seed = rng.next_u64() % 4096;
        let s = gen.generate(seed);
        let z = Pose::new(
            rng.range(-500.0, 500.0),
            rng.range(-500.0, 500.0),
            rng.range(-std::f64::consts::PI, std::f64::consts::PI),
        );
        let s2 = transform_scenario(&s, &z);
        let t = s.n_steps() - 1;
        for i in 0..s.n_agents() {
            for j in 0..s.n_agents() {
                let r1 = s.states[t][i].pose.relative_to(&s.states[t][j].pose);
                let r2 = s2.states[t][i].pose.relative_to(&s2.states[t][j].pose);
                if (r1.x - r2.x).abs() > 1e-9
                    || (r1.y - r2.y).abs() > 1e-9
                    || wrap_angle(r1.theta - r2.theta).abs() > 1e-9
                {
                    return Err(format!(
                        "seed {seed} family {:?}: rel pose ({i},{j}) {r1:?} vs {r2:?}",
                        s.family
                    ));
                }
            }
        }
        Ok(())
    });
}
