//! End-to-end tests for the live introspection server: a real
//! [`ObsServer`] bound to an ephemeral port, exercised over raw
//! `TcpStream` requests (no HTTP client dependency) so the hand-rolled
//! request parsing and response framing are covered too.
//!
//! Each test starts its own server on port 0, so the parallel test
//! harness never shares a listener.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use se2attn::config::ObsConfig;
use se2attn::coordinator::telemetry::ServerStats;
use se2attn::jsonio::Json;
use se2attn::metrics_export::{validate_prometheus, MetricsSnapshot};
use se2attn::obs::alloc::Scope;
use se2attn::obs::http::{ObsServer, ObsSources};

struct Response {
    status: u16,
    content_type: String,
    body: String,
}

/// Issue one raw HTTP request and read the full `Connection: close`
/// response.
fn request(addr: SocketAddr, raw: &str) -> Response {
    let mut stream = TcpStream::connect(addr).expect("connect to obs server");
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    stream.write_all(raw.as_bytes()).expect("send request");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read response");
    let (head, body) = text
        .split_once("\r\n\r\n")
        .expect("response has a header/body separator");
    let status_line = head.lines().next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable status line {status_line:?}"));
    let content_type = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Type: "))
        .unwrap_or("")
        .to_string();
    Response {
        status,
        content_type,
        body: body.to_string(),
    }
}

fn get(addr: SocketAddr, target: &str) -> Response {
    request(
        addr,
        &format!("GET {target} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n"),
    )
}

/// Server + its backing stats, with both shard workers marked live so
/// `/healthz` starts green.
fn start_server(max_queue: usize) -> (ObsServer, Arc<ServerStats>) {
    let stats = Arc::new(ServerStats::with_shards(2));
    stats.shards[0].live.set(1);
    stats.shards[1].live.set(1);
    let cfg = ObsConfig {
        addr: "127.0.0.1:0".to_string(),
        sample_interval: Duration::from_millis(10),
        history: 8,
    };
    let server = ObsServer::start(
        &cfg,
        ObsSources {
            stats: Arc::clone(&stats),
            tracer: None,
            max_queue,
        },
    )
    .expect("bind ephemeral port");
    (server, stats)
}

#[test]
fn metrics_endpoints_serve_live_validated_snapshots() {
    let (server, stats) = start_server(64);
    stats.requests_in.add(7);
    stats.requests_done.add(5);
    stats.shards[0].inflight.set(2);

    let resp = get(server.addr(), "/metrics");
    assert_eq!(resp.status, 200);
    assert!(
        resp.content_type.starts_with("text/plain; version=0.0.4"),
        "Prometheus content type, got {:?}",
        resp.content_type
    );
    let samples = validate_prometheus(&resp.body).expect("scraped exposition validates");
    assert!(samples > 0);
    // the scrape is the same snapshot a direct collect would take: every
    // family name matches, and the counters we pinned read identically
    let collected = MetricsSnapshot::collect(&stats, None);
    for s in &collected.scalars {
        assert!(
            resp.body.contains(&s.name),
            "family {} missing from the scrape",
            s.name
        );
    }
    assert!(resp.body.contains("se2attn_requests_in_total 7"), "{}", resp.body);
    assert!(resp.body.contains("se2attn_requests_done_total 5"), "{}", resp.body);
    // memory attribution rides along on the same endpoint
    assert!(resp.body.contains("se2attn_mem_live_bytes{scope=\"kvcache\"}"));
    assert!(resp.body.contains("se2attn_mem_resident_bytes"));

    let resp = get(server.addr(), "/metrics.json");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.content_type, "application/json");
    let doc = Json::parse(&resp.body).expect("metrics json parses");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("se2attn-metrics-v1")
    );
    let snap = MetricsSnapshot::from_json(&doc).expect("json snapshot round-trips");
    let pinned = snap
        .scalars
        .iter()
        .find(|s| s.name == "se2attn_requests_in_total")
        .expect("pinned counter present");
    assert_eq!(pinned.value, 7);

    server.stop();
}

#[test]
fn healthz_flips_to_503_under_saturation_and_recovers() {
    let (server, stats) = start_server(4);

    let resp = get(server.addr(), "/healthz");
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert!(resp.body.contains("ok: 2 shards live"), "{}", resp.body);

    // queue at capacity -> saturated
    stats.shards[0].queue_depth.set(4);
    let resp = get(server.addr(), "/healthz");
    assert_eq!(resp.status, 503, "{}", resp.body);
    assert!(resp.body.contains("shard 0: queue saturated (4/4)"), "{}", resp.body);

    // drained queue but a dead worker -> still degraded
    stats.shards[0].queue_depth.set(0);
    stats.shards[1].live.set(0);
    let resp = get(server.addr(), "/healthz");
    assert_eq!(resp.status, 503, "{}", resp.body);
    assert!(resp.body.contains("shard 1: worker not running"), "{}", resp.body);

    // full recovery
    stats.shards[1].live.set(1);
    let resp = get(server.addr(), "/healthz");
    assert_eq!(resp.status, 200, "{}", resp.body);

    server.stop();
}

#[test]
fn memory_endpoint_lists_every_scope_in_text_and_json() {
    let (server, _stats) = start_server(64);

    let resp = get(server.addr(), "/memory");
    assert_eq!(resp.status, 200);
    for scope in Scope::ALL {
        assert!(
            resp.body.contains(scope.name()),
            "scope {:?} missing from the table:\n{}",
            scope,
            resp.body
        );
    }

    let resp = get(server.addr(), "/memory?format=json");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.content_type, "application/json");
    Json::parse(&resp.body).expect("memory report json parses");

    server.stop();
}

#[test]
fn vars_serves_bounded_sampler_history_with_watermarks() {
    let (server, stats) = start_server(64);
    stats.shards[0].inflight.set(3);

    // poll until the background sampler has observed inflight=3 (its
    // first reading may predate the set() above)
    let deadline = Instant::now() + Duration::from_secs(5);
    let doc = loop {
        let resp = get(server.addr(), "/vars?watch=3");
        assert_eq!(resp.status, 200);
        let doc = Json::parse(&resp.body).expect("vars json parses");
        let peak_inflight = doc
            .get("watermarks")
            .and_then(|w| w.get("inflight"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        if peak_inflight >= 3.0 {
            break doc;
        }
        assert!(
            Instant::now() < deadline,
            "sampler never observed inflight=3 (watermark {peak_inflight})"
        );
        std::thread::sleep(Duration::from_millis(20));
    };
    let samples = doc.get("samples").and_then(|s| s.as_arr()).unwrap();
    assert!(!samples.is_empty() && samples.len() <= 3, "watch=3 must cap the tail");
    let last = samples.last().unwrap();
    assert!(
        last.get("resident_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
        "a live process always has resident heap bytes"
    );

    server.stop();
}

#[test]
fn unknown_paths_and_methods_are_rejected() {
    let (server, _stats) = start_server(64);

    let resp = get(server.addr(), "/");
    assert_eq!(resp.status, 200);
    assert!(resp.body.contains("/metrics"), "index lists the endpoints");

    // tracing disabled in this source bundle
    let resp = get(server.addr(), "/trace");
    assert_eq!(resp.status, 404);
    assert!(resp.body.contains("tracing disabled"), "{}", resp.body);

    let resp = get(server.addr(), "/no-such-endpoint");
    assert_eq!(resp.status, 404);

    let resp = request(
        server.addr(),
        "POST /metrics HTTP/1.1\r\nHost: test\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(resp.status, 405);
    assert!(resp.body.contains("only GET"), "{}", resp.body);

    server.stop();
}
