//! Failure-injection and edge-case tests for the non-PJRT layers: the
//! system must fail loudly and cleanly, never silently.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use se2attn::config::{Method, ProcConfig, SimConfig, SystemConfig};
use se2attn::coordinator::batcher::{Batcher, BatcherConfig};
use se2attn::coordinator::wire::{Frame, WIRE_MAGIC, WIRE_VERSION};
use se2attn::coordinator::{AdmissionConfig, ProcServer, RolloutRequest};
use se2attn::dataset;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::proplite::check;
use se2attn::runtime::Manifest;
use se2attn::tokenizer::{ActionCodebook, Tokenizer};

#[test]
fn system_config_missing_dir_is_loud() {
    let err = SystemConfig::load("/nonexistent/artifacts").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "actionable message: {msg}");
}

#[test]
fn system_config_rejects_corrupt_index() {
    let dir = std::env::temp_dir().join("se2attn_corrupt_index");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("index.json"), "{not json").unwrap();
    assert!(SystemConfig::load(&dir).is_err());
    std::fs::write(dir.join("index.json"), r#"{"artifacts": []}"#).unwrap();
    let err = SystemConfig::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("config"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_rejects_malformed_entries() {
    for bad in [
        r#"{"inputs": [], "outputs": []}"#,                      // no name
        r#"{"name": "x", "outputs": []}"#,                       // no inputs
        r#"{"name": "x", "inputs": [{"name": "a"}], "outputs": []}"#, // no shape
        r#"{"name":"x","inputs":[{"name":"a","shape":[1],"dtype":"bf16"}],"outputs":[]}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "{bad}");
    }
}

#[test]
fn shard_reader_survives_truncation_fuzz() {
    // write a valid shard, then truncate at every prefix length band:
    // must error, never panic or return wrong data silently.
    let sim = SimConfig::default();
    let model = test_model_config();
    let tok = Tokenizer::new(&model, &sim);
    let ex = dataset::generate_examples(&sim, &tok, 0, 3);
    let dir = std::env::temp_dir().join("se2attn_fuzz_shard");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("full.shard");
    dataset::write_shard(&path, &ex).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let mut rng = Rng::new(0);
    for _ in 0..40 {
        let cut = rng.below(bytes.len().max(1));
        let trunc_path = dir.join("trunc.shard");
        std::fs::write(&trunc_path, &bytes[..cut]).unwrap();
        match dataset::read_shard(&trunc_path) {
            Ok(got) => {
                // only acceptable if truncation landed beyond all examples
                assert_eq!(got, ex, "truncated read must not fabricate data");
            }
            Err(_) => {}
        }
    }
    // bit-flip fuzz on the header
    for i in 0..12.min(bytes.len()) {
        let mut corrupted = bytes.clone();
        corrupted[i] ^= 0xFF;
        let p = dir.join("corrupt.shard");
        std::fs::write(&p, &corrupted).unwrap();
        let _ = dataset::read_shard(&p); // must not panic
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batcher_under_storm_conserves_and_rejects() {
    check("batcher storm", 20, |rng| {
        let cfg = BatcherConfig {
            batch_size: 1 + rng.below(4),
            max_wait: std::time::Duration::from_millis(0),
            max_queue: 1 + rng.below(16),
        };
        let cap = cfg.max_queue;
        let mut b: Batcher<usize> = Batcher::new(cfg);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..100 {
            match b.push(i) {
                Ok(()) => accepted.push(i),
                Err(_) => rejected += 1,
            }
            // occasionally drain
            if rng.bernoulli(0.3) {
                let far = std::time::Instant::now()
                    + std::time::Duration::from_secs(1);
                while let Some(ready) = b.poll(far) {
                    for item in ready.items {
                        let pos = accepted.iter().position(|&x| x == item);
                        match pos {
                            Some(p) if p == 0 => {
                                accepted.remove(0);
                            }
                            _ => return Err(format!("out of order: {item}")),
                        }
                    }
                }
            }
            if b.len() > cap {
                return Err("queue exceeded cap".into());
            }
        }
        // conservation is the invariant; rejections happen whenever the
        // storm outpaces draining (cannot be guaranteed per-seed, so only
        // sanity-check that counting is consistent)
        if rejected + accepted.len() + 0 > 100 {
            return Err("accounting error".into());
        }
        Ok(())
    });
}

#[test]
fn codebook_is_total_over_i32_range() {
    let cb = ActionCodebook::default_for(64);
    // decode must be safe for any id the model could emit
    for id in 0..64 {
        let a = cb.decode(id);
        assert!(a.accel.is_finite() && a.yaw_rate.is_finite());
    }
    // encode must be safe for wild actions (clamps to edge bins)
    for (acc, yaw) in [(1e9, -1e9), (f64::MIN, f64::MAX), (0.0, 0.0)] {
        let id = cb.encode(&se2attn::sim::KinematicAction {
            accel: acc,
            yaw_rate: yaw,
        });
        assert!(id < 64);
    }
}

#[test]
fn json_parser_never_panics_on_fuzz() {
    let mut rng = Rng::new(9);
    let alphabet = b"{}[]\",:.0123456789eE+-truefalsenull \\n";
    for _ in 0..2000 {
        let len = rng.below(64);
        let s: String = (0..len)
            .map(|_| *rng.choice(alphabet) as char)
            .collect();
        let _ = Json::parse(&s); // must not panic
    }
}

#[test]
fn tokenizer_rejects_short_windows() {
    let sim = SimConfig::default();
    let model = test_model_config();
    let tok = Tokenizer::new(&model, &sim);
    let gen = se2attn::sim::ScenarioGenerator::new(sim.clone());
    let s = gen.generate(0);
    let result = std::panic::catch_unwind(|| {
        // t0 too small for the history window: must assert, not corrupt
        tok.tokenize_scenario(&s, 2)
    });
    assert!(result.is_err());
}

#[test]
fn router_rejection_counting() {
    let mut r: se2attn::coordinator::Router<u8> = se2attn::coordinator::Router::new();
    r.deploy(Method::Se2Fourier, 1);
    assert!(r.route(Method::Abs).is_none());
    assert!(r.route(Method::Abs).is_none());
    assert_eq!(r.rejected.get(), 2);
    assert_eq!(r.routed.get(), 0);
}

fn test_model_config() -> se2attn::config::ModelConfig {
    se2attn::config::ModelConfig {
        spatial_scales: vec![1.0, 0.5],
        batch_size: 4,
        ..se2attn::config::ModelConfig::synthetic()
    }
}

// ---------------------------------------------------------------------------
// Wire-protocol fuzz against a live ProcServer coordinator (ISSUE 10):
// truncated frames, oversized length prefixes, garbage magic bytes and
// mid-frame disconnects must all surface as typed, counted errors at the
// coordinator — never a panic, never an unbounded hang.
// ---------------------------------------------------------------------------

/// A one-slot coordinator with no children of its own: the tests below
/// play both the attacker and (when needed) a hand-rolled worker.
fn fuzz_fleet() -> ProcServer {
    ProcServer::start(
        1,
        ProcConfig {
            manual_workers: true,
            respawn: false,
            ..ProcConfig::default()
        },
        AdmissionConfig::default(),
        Vec::new(),
    )
    .expect("fuzz fleet start")
}

fn wait_until(ms: u64, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Deliver `bytes` on a fresh connection, then close it; waits (bounded)
/// for the coordinator to hang up on us so the error is counted before
/// the caller checks the stats.
fn attack(server: &ProcServer, bytes: &[u8]) {
    let mut s = TcpStream::connect(server.addr()).expect("connect to coordinator");
    let _ = s.write_all(bytes);
    let _ = s.flush();
    let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
    let mut sink = [0u8; 16];
    // errors with UnexpectedEof as soon as the coordinator drops us
    let _ = s.read_exact(&mut sink);
}

/// A well-formed worker handshake: true iff the coordinator answers
/// `HelloAck` — the liveness probe proving the fuzz did not wedge it.
fn handshake_probe(server: &ProcServer) -> bool {
    let Ok(mut s) = TcpStream::connect(server.addr()) else {
        return false;
    };
    if s.set_read_timeout(Some(Duration::from_secs(5))).is_err() {
        return false;
    }
    let hello = Frame::Hello {
        version: WIRE_VERSION,
        worker_id: 0,
        pid: std::process::id(),
        token: server.token(),
    };
    if hello.write_to(&mut s).is_err() {
        return false;
    }
    matches!(Frame::read_from(&mut s), Ok(Frame::HelloAck))
}

#[test]
fn proc_coordinator_survives_handshake_fuzz() {
    let server = fuzz_fleet();
    let stats = server.stats();
    let start = Instant::now();

    // four targeted attacks, one connection each
    // garbage magic bytes
    let garbage = b"\xde\xad\xbe\xefnot a frame at all".to_vec();
    // oversized length prefix: claims 4 GiB, must be rejected before
    // any allocation
    let mut oversize = WIRE_MAGIC.to_le_bytes().to_vec();
    oversize.extend_from_slice(&u32::MAX.to_le_bytes());
    // truncated frame: promises 100 payload bytes, delivers 10, closes
    let mut trunc = WIRE_MAGIC.to_le_bytes().to_vec();
    trunc.extend_from_slice(&100u32.to_le_bytes());
    trunc.extend_from_slice(&[7u8; 10]);
    // mid-frame disconnect: header only, zero payload bytes
    let mut header_only = WIRE_MAGIC.to_le_bytes().to_vec();
    header_only.extend_from_slice(&64u32.to_le_bytes());
    let frames = vec![garbage, oversize, trunc, header_only];
    let targeted = frames.len() as u64;
    for f in &frames {
        attack(&server, f);
    }

    // random-bytes fuzz: every connection must resolve to exactly one
    // typed wire error (whatever the bytes decode to, a random token
    // can never pass the handshake)
    let mut rng = Rng::new(0xF422);
    let n_random = 40u64;
    for _ in 0..n_random {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        attack(&server, &bytes);
    }

    // one counted error per hostile connection — no more, no fewer
    let expected = targeted + n_random;
    assert!(
        wait_until(5_000, || stats.migration.wire_errors.get() == expected),
        "wire errors: want {expected}, got {} (bounded wait)",
        stats.migration.wire_errors.get()
    );
    // and the coordinator still accepts a well-formed worker afterwards
    assert!(
        wait_until(5_000, || handshake_probe(&server)),
        "coordinator stopped answering valid handshakes after the fuzz"
    );
    assert!(
        start.elapsed() < Duration::from_secs(60),
        "fuzz sweep must complete in bounded time"
    );
}

#[test]
fn proc_reader_fuzz_after_handshake_is_contained() {
    let server = fuzz_fleet();
    let stats = server.stats();

    // a legitimate hand-rolled worker session...
    let mut s = TcpStream::connect(server.addr()).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    Frame::Hello {
        version: WIRE_VERSION,
        worker_id: 0,
        pid: std::process::id(),
        token: server.token(),
    }
    .write_to(&mut s)
    .expect("send hello");
    assert!(matches!(Frame::read_from(&mut s), Ok(Frame::HelloAck)));
    assert!(wait_until(2_000, || stats.shards[0].live.get() == 1));

    // ...that turns hostile: garbage on the established session is a
    // typed wire error and an unclean worker death, not a panic
    s.write_all(b"\x00\x00\x00\x00 bad magic mid-session").unwrap();
    s.flush().unwrap();
    assert!(wait_until(5_000, || stats.migration.wire_errors.get() >= 1));
    assert!(wait_until(5_000, || stats.migration.worker_deaths.get() == 1));
    assert_eq!(stats.shards[0].live.get(), 0);

    // with the only worker dead (manual fleet: no respawn), submission
    // fails fast with a typed routing error instead of hanging
    let gen = se2attn::sim::ScenarioGenerator::new(SimConfig::default());
    let req = RolloutRequest {
        scenario: gen.generate(0),
        t0: SimConfig::default().history_steps - 1,
        n_samples: 1,
        temperature: 1.0,
        seed: 0,
    };
    let err = server.call(Method::Se2Fourier, req).unwrap_err();
    assert!(
        format!("{err:#}").contains("no live worker"),
        "typed routing error, got: {err:#}"
    );
}

#[test]
fn stalled_client_does_not_block_the_accept_loop() {
    let server = fuzz_fleet();
    // connects and sends nothing: parked in its own handshake thread
    // until `connect_timeout`, which is longer than this whole test
    let _staller = TcpStream::connect(server.addr()).expect("staller connect");
    // a well-formed handshake still completes promptly alongside it
    assert!(
        wait_until(5_000, || handshake_probe(&server)),
        "a stalled client must not wedge the accept loop"
    );
}
