//! Failure-injection and edge-case tests for the non-PJRT layers: the
//! system must fail loudly and cleanly, never silently.

use se2attn::config::{Method, SimConfig, SystemConfig};
use se2attn::coordinator::batcher::{Batcher, BatcherConfig};
use se2attn::dataset;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::proplite::check;
use se2attn::runtime::Manifest;
use se2attn::tokenizer::{ActionCodebook, Tokenizer};

#[test]
fn system_config_missing_dir_is_loud() {
    let err = SystemConfig::load("/nonexistent/artifacts").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "actionable message: {msg}");
}

#[test]
fn system_config_rejects_corrupt_index() {
    let dir = std::env::temp_dir().join("se2attn_corrupt_index");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("index.json"), "{not json").unwrap();
    assert!(SystemConfig::load(&dir).is_err());
    std::fs::write(dir.join("index.json"), r#"{"artifacts": []}"#).unwrap();
    let err = SystemConfig::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("config"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_rejects_malformed_entries() {
    for bad in [
        r#"{"inputs": [], "outputs": []}"#,                      // no name
        r#"{"name": "x", "outputs": []}"#,                       // no inputs
        r#"{"name": "x", "inputs": [{"name": "a"}], "outputs": []}"#, // no shape
        r#"{"name":"x","inputs":[{"name":"a","shape":[1],"dtype":"bf16"}],"outputs":[]}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "{bad}");
    }
}

#[test]
fn shard_reader_survives_truncation_fuzz() {
    // write a valid shard, then truncate at every prefix length band:
    // must error, never panic or return wrong data silently.
    let sim = SimConfig::default();
    let model = test_model_config();
    let tok = Tokenizer::new(&model, &sim);
    let ex = dataset::generate_examples(&sim, &tok, 0, 3);
    let dir = std::env::temp_dir().join("se2attn_fuzz_shard");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("full.shard");
    dataset::write_shard(&path, &ex).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let mut rng = Rng::new(0);
    for _ in 0..40 {
        let cut = rng.below(bytes.len().max(1));
        let trunc_path = dir.join("trunc.shard");
        std::fs::write(&trunc_path, &bytes[..cut]).unwrap();
        match dataset::read_shard(&trunc_path) {
            Ok(got) => {
                // only acceptable if truncation landed beyond all examples
                assert_eq!(got, ex, "truncated read must not fabricate data");
            }
            Err(_) => {}
        }
    }
    // bit-flip fuzz on the header
    for i in 0..12.min(bytes.len()) {
        let mut corrupted = bytes.clone();
        corrupted[i] ^= 0xFF;
        let p = dir.join("corrupt.shard");
        std::fs::write(&p, &corrupted).unwrap();
        let _ = dataset::read_shard(&p); // must not panic
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batcher_under_storm_conserves_and_rejects() {
    check("batcher storm", 20, |rng| {
        let cfg = BatcherConfig {
            batch_size: 1 + rng.below(4),
            max_wait: std::time::Duration::from_millis(0),
            max_queue: 1 + rng.below(16),
        };
        let cap = cfg.max_queue;
        let mut b: Batcher<usize> = Batcher::new(cfg);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..100 {
            match b.push(i) {
                Ok(()) => accepted.push(i),
                Err(_) => rejected += 1,
            }
            // occasionally drain
            if rng.bernoulli(0.3) {
                let far = std::time::Instant::now()
                    + std::time::Duration::from_secs(1);
                while let Some(ready) = b.poll(far) {
                    for item in ready.items {
                        let pos = accepted.iter().position(|&x| x == item);
                        match pos {
                            Some(p) if p == 0 => {
                                accepted.remove(0);
                            }
                            _ => return Err(format!("out of order: {item}")),
                        }
                    }
                }
            }
            if b.len() > cap {
                return Err("queue exceeded cap".into());
            }
        }
        // conservation is the invariant; rejections happen whenever the
        // storm outpaces draining (cannot be guaranteed per-seed, so only
        // sanity-check that counting is consistent)
        if rejected + accepted.len() + 0 > 100 {
            return Err("accounting error".into());
        }
        Ok(())
    });
}

#[test]
fn codebook_is_total_over_i32_range() {
    let cb = ActionCodebook::default_for(64);
    // decode must be safe for any id the model could emit
    for id in 0..64 {
        let a = cb.decode(id);
        assert!(a.accel.is_finite() && a.yaw_rate.is_finite());
    }
    // encode must be safe for wild actions (clamps to edge bins)
    for (acc, yaw) in [(1e9, -1e9), (f64::MIN, f64::MAX), (0.0, 0.0)] {
        let id = cb.encode(&se2attn::sim::KinematicAction {
            accel: acc,
            yaw_rate: yaw,
        });
        assert!(id < 64);
    }
}

#[test]
fn json_parser_never_panics_on_fuzz() {
    let mut rng = Rng::new(9);
    let alphabet = b"{}[]\",:.0123456789eE+-truefalsenull \\n";
    for _ in 0..2000 {
        let len = rng.below(64);
        let s: String = (0..len)
            .map(|_| *rng.choice(alphabet) as char)
            .collect();
        let _ = Json::parse(&s); // must not panic
    }
}

#[test]
fn tokenizer_rejects_short_windows() {
    let sim = SimConfig::default();
    let model = test_model_config();
    let tok = Tokenizer::new(&model, &sim);
    let gen = se2attn::sim::ScenarioGenerator::new(sim.clone());
    let s = gen.generate(0);
    let result = std::panic::catch_unwind(|| {
        // t0 too small for the history window: must assert, not corrupt
        tok.tokenize_scenario(&s, 2)
    });
    assert!(result.is_err());
}

#[test]
fn router_rejection_counting() {
    let mut r: se2attn::coordinator::Router<u8> = se2attn::coordinator::Router::new();
    r.deploy(Method::Se2Fourier, 1);
    assert!(r.route(Method::Abs).is_none());
    assert!(r.route(Method::Abs).is_none());
    assert_eq!(r.rejected.get(), 2);
    assert_eq!(r.routed.get(), 0);
}

fn test_model_config() -> se2attn::config::ModelConfig {
    se2attn::config::ModelConfig {
        spatial_scales: vec![1.0, 0.5],
        batch_size: 4,
        ..se2attn::config::ModelConfig::synthetic()
    }
}
