//! Offline stand-in for the `anyhow` crate: the exact API subset this
//! workspace uses (`Result`, `Error`, `anyhow!`, `bail!`, `Context` on
//! `Result` and `Option`), implemented dependency-free so `cargo build`
//! works with no network access.  Swapping back to crates.io anyhow is a
//! one-line change in `rust/Cargo.toml`; no call site changes.
//!
//! Semantics mirrored from upstream:
//! * `{}` displays the outermost message only;
//! * `{:#}` displays the whole context chain, colon-separated;
//! * `{:?}` displays the message plus a "Caused by:" list;
//! * any `E: std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// `Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error: the outermost message plus the causes below it.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message (mirror of `Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first (mirror of `Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut stack = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            stack.push(e);
            cur = e.source.as_deref();
        }
        stack.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow-compatible).
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, e) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our context chain so `{:#}`
        // keeps all the detail.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.unwrap()
    }
}

/// Private dispatch trait so [`Context`] covers both `Result<T, E>` for
/// std errors *and* `Result<T, Error>` (the same sealed-trait trick
/// upstream anyhow uses).
mod private {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Mirror of `anyhow::Context`: attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Mirror of `anyhow::anyhow!`: format a message into an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Mirror of `anyhow::bail!`: early-return an error from the enclosing fn.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_display() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading index")
            .unwrap_err()
            .context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("loading config: reading index"), "{full}");
        assert!(full.contains("gone"), "{full}");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        fn f() -> Result<()> {
            bail!("boom {}", 1)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 1");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("utf-8"));
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root")
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
    }
}
