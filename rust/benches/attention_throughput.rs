//! Attention wall-clock across methods and scene sizes ("practical to
//! implement", paper Sec. I/IV): native linear (Alg. 2) vs native quadratic
//! (Alg. 1) per method, plus the AOT Pallas/PJRT artifact at its lowered
//! shape.
//!
//! Expected shape: quadratic grows ~N^2 and overtakes the linear path by
//! N in the hundreds; SE(2) Fourier pays a constant-factor premium over
//! 2D RoPE (projected width c = (4F+2)/6 * d) but keeps the same scaling.

use se2attn::attention::{linear, quadratic, AttnProblem};
use se2attn::benchlib::{bench_quick, record_row, Table};
use se2attn::config::Method;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::runtime::{Engine, HostTensor};

const D: usize = 48;
const F: usize = 12;

struct Data {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    poses: Vec<Pose>,
    tq: Vec<i32>,
}

fn data(n: usize) -> Data {
    let mut rng = Rng::new(n as u64 ^ 0xBEEF);
    Data {
        q: (0..n * D).map(|_| rng.normal() as f32).collect(),
        k: (0..n * D).map(|_| rng.normal() as f32).collect(),
        v: (0..n * D).map(|_| rng.normal() as f32).collect(),
        poses: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        tq: (0..n).map(|i| (i / 8) as i32).collect(),
    }
}

fn problem<'a>(m: Method, d: &'a Data, scales: &'a [f64]) -> AttnProblem<'a> {
    AttnProblem {
        method: m,
        d: D,
        fourier_f: F,
        scales,
        q: &d.q,
        k: &d.k,
        v: &d.v,
        pose_q: &d.poses,
        pose_k: &d.poses,
        tq: &d.tq,
        tk: &d.tq,
    }
}

fn main() {
    let full = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full {
        &[64, 128, 256, 512, 1024, 2048]
    } else {
        &[64, 128, 256, 512]
    };
    let scales = [1.0, 0.5, 0.25, 0.125];

    println!("# Attention throughput — native CPU implementations (d={D}, F={F})\n");
    let mut table = Table::new(&["method", "N", "linear ms", "quadratic ms", "quad/lin"]);
    for &n in sizes {
        let d = data(n);
        for m in Method::ALL {
            let p = problem(m, &d, &scales);
            let lin = bench_quick(|| {
                std::hint::black_box(linear::attention(&p));
            });
            // quadratic at large N is exactly the cost being demonstrated —
            // cap it to keep default bench time sane
            let quad_ms = if n <= 512 || full {
                let s = bench_quick(|| {
                    std::hint::black_box(quadratic::attention(&p));
                });
                s.mean_ms()
            } else {
                f64::NAN
            };
            table.row(vec![
                m.name().into(),
                n.to_string(),
                format!("{:.3}", lin.mean_ms()),
                if quad_ms.is_nan() { "-".into() } else { format!("{quad_ms:.3}") },
                if quad_ms.is_nan() { "-".into() } else { format!("{:.1}x", quad_ms / lin.mean_ms()) },
            ]);
            record_row(
                "attention_throughput",
                Json::obj(vec![
                    ("method", Json::Str(m.name().into())),
                    ("n", Json::Num(n as f64)),
                    ("linear_ms", Json::Num(lin.mean_ms())),
                    ("quadratic_ms", Json::Num(quad_ms)),
                ]),
            );
        }
    }
    table.print();

    // ---- AOT artifact timing (the production path) ----------------------
    println!("\n# AOT Pallas/PJRT artifacts at lowered shape (N=64, single head)");
    match Engine::cpu("artifacts") {
        Ok(engine) => {
            let n = 64;
            let d = data(n);
            let pose_flat: Vec<f32> = d
                .poses
                .iter()
                .flat_map(|p| [p.x as f32, p.y as f32, p.theta as f32])
                .collect();
            let mut t = Table::new(&["artifact", "mean ms", "p95 ms"]);
            let mut names: Vec<String> =
                Method::ALL.iter().map(|m| format!("attn_{}", m.name())).collect();
            // the fused single-kernel variant (projection + SDPA +
            // unprojection in one Pallas call — see kernels/fused_attn.py)
            names.push("attn_se2fourier_fused".to_string());
            for name in names {
                match engine.load(&name) {
                    Ok(artifact) => {
                        let inputs = vec![
                            HostTensor::f32(vec![n, D], d.q.clone()),
                            HostTensor::f32(vec![n, D], d.k.clone()),
                            HostTensor::f32(vec![n, D], d.v.clone()),
                            HostTensor::f32(vec![n, 3], pose_flat.clone()),
                            HostTensor::i32(vec![n], d.tq.clone()),
                        ];
                        let stats = bench_quick(|| {
                            std::hint::black_box(artifact.execute(&inputs).unwrap());
                        });
                        t.row(vec![
                            name.clone(),
                            format!("{:.3}", stats.mean_ms()),
                            format!("{:.3}", stats.p95_ns / 1e6),
                        ]);
                        record_row(
                            "attention_throughput",
                            Json::obj(vec![
                                ("artifact", Json::Str(name)),
                                ("mean_ms", Json::Num(stats.mean_ms())),
                            ]),
                        );
                    }
                    Err(e) => println!("  (skipping {name}: {e})"),
                }
            }
            t.print();
        }
        Err(e) => println!("(PJRT unavailable: {e} — run `make artifacts` first)"),
    }
    println!("\nattention_throughput OK");
}
