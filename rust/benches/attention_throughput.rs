//! Attention wall-clock across methods and scene sizes ("practical to
//! implement", paper Sec. I/IV): native linear (Alg. 2) vs native quadratic
//! (Alg. 1) per method, the blocked multithreaded flash kernel vs its
//! scalar oracle, plus the AOT Pallas/PJRT artifact at its lowered shape.
//!
//! Modes (see `benchlib::BenchMode`):
//! * `SE2ATTN_BENCH_SMOKE=1` — CI perf-regression gate: small sizes, few
//!   iterations, and the process **exits nonzero** if the blocked kernel's
//!   mean is slower than the scalar oracle at the largest smoke size, or
//!   if the fused SE(2)-Fourier path is < 1.5x over project-then-attend
//!   at the largest smoke decode window.
//! * default — developer-scale sweep (includes the 1024-token kernel row
//!   backing the ">= 2x at n = m = 1024 with 4 threads" acceptance bar).
//! * `SE2ATTN_BENCH_FULL=1` — paper-scale sweep.
//!
//! Every run overwrites `BENCH_attention.json` (rows embed
//! `benchlib::Stats::to_json`) so CI archives the perf trajectory.

use se2attn::attention::kernel::{flash_sdpa_blocked, flash_sdpa_scalar, KernelConfig};
use se2attn::attention::{linear, quadratic, AttnProblem};
use se2attn::benchlib::{bench_mode, record_row, write_bench_json, BenchMode, Table};
use se2attn::config::Method;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::runtime::{Engine, HostTensor};

const D: usize = 48;
const F: usize = 12;

struct Data {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    poses: Vec<Pose>,
    tq: Vec<i32>,
}

fn data(n: usize) -> Data {
    let mut rng = Rng::new(n as u64 ^ 0xBEEF);
    Data {
        q: (0..n * D).map(|_| rng.normal() as f32).collect(),
        k: (0..n * D).map(|_| rng.normal() as f32).collect(),
        v: (0..n * D).map(|_| rng.normal() as f32).collect(),
        poses: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        tq: (0..n).map(|i| (i / 8) as i32).collect(),
    }
}

fn problem<'a>(m: Method, d: &'a Data, scales: &'a [f64]) -> AttnProblem<'a> {
    AttnProblem {
        method: m,
        d: D,
        fourier_f: F,
        scales,
        q: &d.q,
        k: &d.k,
        v: &d.v,
        pose_q: &d.poses,
        pose_k: &d.poses,
        tq: &d.tq,
        tk: &d.tq,
    }
}

/// Linear (Alg. 2, blocked kernel) vs quadratic (Alg. 1) per method.
fn algorithms_section(mode: BenchMode, rows: &mut Vec<Json>) {
    let sizes: &[usize] = mode.pick(
        &[64, 128],
        &[64, 128, 256, 512],
        &[64, 128, 256, 512, 1024, 2048],
    );
    let scales = [1.0, 0.5, 0.25, 0.125];

    println!("# Attention throughput — native CPU implementations (d={D}, F={F})\n");
    let mut table = Table::new(&["method", "N", "linear ms", "quadratic ms", "quad/lin"]);
    for &n in sizes {
        let d = data(n);
        for m in Method::ALL {
            let p = problem(m, &d, &scales);
            let lin = bench_mode(mode, || {
                std::hint::black_box(linear::attention(&p));
            });
            // quadratic at large N is exactly the cost being demonstrated —
            // cap it to keep default bench time sane
            let quad_ms = if n <= 512 || mode.is_full() {
                let s = bench_mode(mode, || {
                    std::hint::black_box(quadratic::attention(&p));
                });
                s.mean_ms()
            } else {
                f64::NAN
            };
            table.row(vec![
                m.name().into(),
                n.to_string(),
                format!("{:.3}", lin.mean_ms()),
                if quad_ms.is_nan() { "-".into() } else { format!("{quad_ms:.3}") },
                if quad_ms.is_nan() { "-".into() } else { format!("{:.1}x", quad_ms / lin.mean_ms()) },
            ]);
            let row = Json::obj(vec![
                ("bench", Json::Str("algorithms".into())),
                ("method", Json::Str(m.name().into())),
                ("n", Json::Num(n as f64)),
                ("linear", lin.to_json()),
                ("linear_ms", Json::Num(lin.mean_ms())),
                ("quadratic_ms", Json::Num(quad_ms)),
            ]);
            record_row("attention_throughput", row.clone());
            rows.push(row);
        }
    }
    table.print();
}

/// Blocked multithreaded kernel vs the scalar oracle on identical
/// pre-projected se2fourier tensors (c = (4F+2)/6 * d = 400).  Returns
/// the verdict at the largest size: `Some(true)` = blocked (4 threads)
/// beat the scalar oracle.
fn kernel_section(mode: BenchMode, rows: &mut Vec<Json>) -> Option<bool> {
    let sizes: &[usize] = mode.pick(&[64, 256], &[256, 1024], &[256, 1024, 2048]);
    let scales = [1.0, 0.5, 0.25, 0.125];
    println!(
        "\n# Flash kernel: blocked (block_m={}, lanes={}) vs scalar oracle, se2fourier\n",
        KernelConfig::DEFAULT_BLOCK_M,
        KernelConfig::DEFAULT_LANES,
    );
    let mut table = Table::new(&[
        "N=M",
        "c",
        "scalar ms",
        "blocked x1 ms",
        "blocked x4 ms",
        "x4 speedup",
        "verdict",
    ]);
    let mut last_ok = None;
    for &n in sizes {
        let d = data(n);
        let p = problem(Method::Se2Fourier, &d, &scales);
        let prj = linear::project(&p);
        let c = prj.c;
        let mut out = vec![0.0f32; n * c];

        let scalar = bench_mode(mode, || {
            flash_sdpa_scalar(&prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out);
            std::hint::black_box(&out);
        });
        let t1 = KernelConfig::fixed(KernelConfig::DEFAULT_BLOCK_M, KernelConfig::DEFAULT_LANES, 1);
        let blocked1 = bench_mode(mode, || {
            flash_sdpa_blocked(
                &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &t1,
            );
            std::hint::black_box(&out);
        });
        let t4 = KernelConfig::fixed(KernelConfig::DEFAULT_BLOCK_M, KernelConfig::DEFAULT_LANES, 4);
        let blocked4 = bench_mode(mode, || {
            flash_sdpa_blocked(
                &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &t4,
            );
            std::hint::black_box(&out);
        });

        let speedup = scalar.mean_ns / blocked4.mean_ns;
        let ok = blocked4.mean_ns < scalar.mean_ns;
        // acceptance bar (ISSUE 4): >= 2x at n = m = 1024 with 4 threads
        let verdict = if n >= 1024 {
            if speedup >= 2.0 { "PASS (>=2x)".into() } else { format!("FAIL ({speedup:.2}x < 2x)") }
        } else if ok {
            "PASS (faster)".into()
        } else {
            format!("FAIL ({speedup:.2}x)")
        };
        table.row(vec![
            n.to_string(),
            c.to_string(),
            format!("{:.3}", scalar.mean_ms()),
            format!("{:.3}", blocked1.mean_ms()),
            format!("{:.3}", blocked4.mean_ms()),
            format!("{speedup:.2}x"),
            verdict,
        ]);
        let row = Json::obj(vec![
            ("bench", Json::Str("kernel".into())),
            ("n", Json::Num(n as f64)),
            ("c", Json::Num(c as f64)),
            ("scalar", scalar.to_json()),
            ("blocked_t1", blocked1.to_json()),
            ("blocked_t4", blocked4.to_json()),
            ("speedup_t4", Json::Num(speedup)),
        ]);
        record_row("attention_throughput", row.clone());
        rows.push(row);
        last_ok = Some(ok);
    }
    table.print();
    last_ok
}

/// Fused projection vs project-then-attend at decode shapes (ISSUE 9 /
/// ROADMAP fused-kernel gate): `n_new` fresh query rows attend a window
/// of `m` raw keys+poses.  The fused path computes phi_k inside the key
/// loop (zero projected intermediates); project-then-attend materializes
/// the full (2m x c) k~/v~ first.  Returns the verdict at the largest
/// size: `Some(true)` = fused >= 1.5x.
fn fused_section(mode: BenchMode, rows: &mut Vec<Json>) -> Option<bool> {
    let windows: &[usize] = mode.pick(
        &[1024, 4096],
        &[1024, 4096, 16384],
        &[1024, 4096, 16384, 65536],
    );
    let n_new = 8usize;
    let scales = [1.0, 0.5, 0.25, 0.125];
    let cfg = KernelConfig::fixed(KernelConfig::DEFAULT_BLOCK_M, KernelConfig::DEFAULT_LANES, 4);

    println!("\n# Fused projection vs project-then-attend, se2fourier decode shapes (n_new={n_new})\n");
    let mut table = Table::new(&[
        "keys m",
        "project+attend ms",
        "fused ms",
        "speedup",
        "proj peak KiB",
        "fused peak KiB",
        "verdict",
    ]);
    let mut last_ok = None;
    for &m in windows {
        let d = data(m);
        let mut rng = Rng::new(m as u64 ^ 0xFACE);
        let q: Vec<f32> = (0..n_new * D).map(|_| rng.normal() as f32).collect();
        let pose_q: Vec<Pose> = (0..n_new)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect();
        // fresh decode rows: visible to the whole window
        let tq = vec![i32::MAX; n_new];
        let p = AttnProblem {
            method: Method::Se2Fourier,
            d: D,
            fourier_f: F,
            scales: &scales,
            q: &q,
            k: &d.k,
            v: &d.v,
            pose_q: &pose_q,
            pose_k: &d.poses,
            tq: &tq,
            tk: &d.tq,
        };
        let projected = bench_mode(mode, || {
            std::hint::black_box(linear::attention_projected_with(&p, &cfg));
        });
        let fused = bench_mode(mode, || {
            std::hint::black_box(linear::attention_fused_with(&p, &cfg));
        });
        // the memory claim, measured on the real outputs (not just bench
        // timing): fused reports zero projection intermediates
        let proj_peak = linear::attention_projected_with(&p, &cfg).peak_temp_bytes;
        let fused_peak = linear::attention_fused_with(&p, &cfg).peak_temp_bytes;
        assert!(
            fused_peak * 4 < proj_peak,
            "fused peak {fused_peak} not well under projected peak {proj_peak}"
        );

        let speedup = projected.mean_ns / fused.mean_ns;
        let ok = speedup >= 1.5;
        table.row(vec![
            m.to_string(),
            format!("{:.3}", projected.mean_ms()),
            format!("{:.3}", fused.mean_ms()),
            format!("{speedup:.2}x"),
            format!("{}", proj_peak / 1024),
            format!("{}", fused_peak / 1024),
            if ok { "PASS (>=1.5x)".into() } else { format!("FAIL ({speedup:.2}x < 1.5x)") },
        ]);
        let row = Json::obj(vec![
            ("bench", Json::Str("fused".into())),
            ("m", Json::Num(m as f64)),
            ("n_new", Json::Num(n_new as f64)),
            ("projected", projected.to_json()),
            ("fused", fused.to_json()),
            ("speedup", Json::Num(speedup)),
            ("projected_peak_bytes", Json::Num(proj_peak as f64)),
            ("fused_peak_bytes", Json::Num(fused_peak as f64)),
        ]);
        record_row("attention_throughput", row.clone());
        rows.push(row);
        last_ok = Some(ok);
    }
    table.print();

    // ungated context row: at prefill shapes (n = m) the recompute factor
    // ceil(n/8) makes project-then-attend the right choice — documenting
    // why attention_with routes by query count (DESIGN.md §18)
    let n = *mode.pick(&[256], &[512], &[1024]).first().unwrap();
    let d = data(n);
    let p = problem(Method::Se2Fourier, &d, &scales);
    let projected = bench_mode(mode, || {
        std::hint::black_box(linear::attention_projected_with(&p, &cfg));
    });
    let fused = bench_mode(mode, || {
        std::hint::black_box(linear::attention_fused_with(&p, &cfg));
    });
    println!(
        "\nprefill n=m={n}: project+attend {:.3} ms vs fused {:.3} ms ({:.2}x) — \
         recompute factor favors materializing k~/v~ at large n",
        projected.mean_ms(),
        fused.mean_ms(),
        projected.mean_ns / fused.mean_ns,
    );
    let row = Json::obj(vec![
        ("bench", Json::Str("fused_prefill".into())),
        ("n", Json::Num(n as f64)),
        ("projected", projected.to_json()),
        ("fused", fused.to_json()),
    ]);
    record_row("attention_throughput", row.clone());
    rows.push(row);
    last_ok
}

/// Observability overhead on the hot kernel path: the same blocked call
/// benched with the tracing/profiling gates off, then with a live tracer
/// (thread ctx installed, Attend spans landing in a ring) plus profiling
/// counters.  Returns the on/off mean ratio; the smoke gate caps it at
/// 1.05x (DESIGN.md §15 overhead budget).  Each leg takes the better of
/// two runs to damp scheduler noise on shared CI runners.
fn overhead_section(mode: BenchMode, rows: &mut Vec<Json>) -> f64 {
    use se2attn::trace::{ProfileGuard, TraceConfig, Tracer};
    let n = *mode.pick(&[256], &[512], &[1024]).first().unwrap();
    let scales = [1.0, 0.5, 0.25, 0.125];
    let d = data(n);
    let p = problem(Method::Se2Fourier, &d, &scales);
    let prj = linear::project(&p);
    let c = prj.c;
    let mut out = vec![0.0f32; n * c];
    let cfg = KernelConfig::fixed(KernelConfig::DEFAULT_BLOCK_M, KernelConfig::DEFAULT_LANES, 4);

    println!("\n# Observability overhead: blocked kernel, tracing+profiling off vs on\n");
    assert!(
        !se2attn::trace::enabled(),
        "tracing must be disabled before the off leg"
    );
    let off_a = bench_mode(mode, || {
        flash_sdpa_blocked(
            &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &cfg,
        );
        std::hint::black_box(&out);
    });
    let off_b = bench_mode(mode, || {
        flash_sdpa_blocked(
            &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &cfg,
        );
        std::hint::black_box(&out);
    });
    let off_ns = off_a.mean_ns.min(off_b.mean_ns);

    let tracer = Tracer::new(
        1,
        TraceConfig {
            enabled: true,
            ring_spans: 4096,
        },
    );
    let _profile = ProfileGuard::enable();
    let ctx = se2attn::trace::install(tracer.shard_ring(0), tracer.epoch());
    let on_a = bench_mode(mode, || {
        flash_sdpa_blocked(
            &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &cfg,
        );
        std::hint::black_box(&out);
    });
    let on_b = bench_mode(mode, || {
        flash_sdpa_blocked(
            &prj.qt, &prj.kt, &prj.vt, p.tq, p.tk, c, prj.eff_scale, &mut out, &cfg,
        );
        std::hint::black_box(&out);
    });
    let on_ns = on_a.mean_ns.min(on_b.mean_ns);
    let (spans, dropped) = tracer.totals();
    drop(ctx);
    drop(tracer);
    assert!(spans > 0, "the on leg must record Attend spans");

    let ratio = on_ns / off_ns;
    let mut table = Table::new(&["N=M", "c", "off ms", "on ms", "on/off", "spans"]);
    table.row(vec![
        n.to_string(),
        c.to_string(),
        format!("{:.3}", off_ns / 1e6),
        format!("{:.3}", on_ns / 1e6),
        format!("{ratio:.3}x"),
        format!("{spans} (+{dropped} dropped)"),
    ]);
    table.print();
    let row = Json::obj(vec![
        ("bench", Json::Str("observability_overhead".into())),
        ("n", Json::Num(n as f64)),
        ("c", Json::Num(c as f64)),
        ("off", off_a.to_json()),
        ("on", on_a.to_json()),
        ("off_ns", Json::Num(off_ns)),
        ("on_ns", Json::Num(on_ns)),
        ("ratio", Json::Num(ratio)),
        ("spans", Json::Num(spans as f64)),
    ]);
    record_row("attention_throughput", row.clone());
    rows.push(row);
    ratio
}

/// AOT artifact timing (the production path) — unchanged from the
/// original bench; skipped gracefully in the offline stub build.
fn artifact_section(rows: &mut Vec<Json>) {
    println!("\n# AOT Pallas/PJRT artifacts at lowered shape (N=64, single head)");
    match Engine::cpu("artifacts") {
        Ok(engine) => {
            let n = 64;
            let d = data(n);
            let pose_flat: Vec<f32> = d
                .poses
                .iter()
                .flat_map(|p| [p.x as f32, p.y as f32, p.theta as f32])
                .collect();
            let mut t = Table::new(&["artifact", "mean ms", "p95 ms"]);
            let mut names: Vec<String> =
                Method::ALL.iter().map(|m| format!("attn_{}", m.name())).collect();
            // the fused single-kernel variant (projection + SDPA +
            // unprojection in one Pallas call — see kernels/fused_attn.py)
            names.push("attn_se2fourier_fused".to_string());
            for name in names {
                match engine.load(&name) {
                    Ok(artifact) => {
                        let inputs = vec![
                            HostTensor::f32(vec![n, D], d.q.clone()),
                            HostTensor::f32(vec![n, D], d.k.clone()),
                            HostTensor::f32(vec![n, D], d.v.clone()),
                            HostTensor::f32(vec![n, 3], pose_flat.clone()),
                            HostTensor::i32(vec![n], d.tq.clone()),
                        ];
                        let stats = se2attn::benchlib::bench_quick(|| {
                            std::hint::black_box(artifact.execute(&inputs).unwrap());
                        });
                        t.row(vec![
                            name.clone(),
                            format!("{:.3}", stats.mean_ms()),
                            format!("{:.3}", stats.p95_ns / 1e6),
                        ]);
                        let row = Json::obj(vec![
                            ("bench", Json::Str("artifact".into())),
                            ("artifact", Json::Str(name)),
                            ("stats", stats.to_json()),
                        ]);
                        record_row("attention_throughput", row.clone());
                        rows.push(row);
                    }
                    Err(e) => println!("  (skipping {name}: {e})"),
                }
            }
            t.print();
        }
        Err(e) => println!("(PJRT unavailable: {e} — run `make artifacts` first)"),
    }
}

fn main() {
    let mode = BenchMode::from_env();
    let mut rows: Vec<Json> = Vec::new();
    algorithms_section(mode, &mut rows);
    let kernel_ok = kernel_section(mode, &mut rows);
    let fused_ok = fused_section(mode, &mut rows);
    let overhead = overhead_section(mode, &mut rows);
    if !mode.is_smoke() {
        artifact_section(&mut rows);
    }
    write_bench_json("BENCH_attention.json", rows).expect("write BENCH_attention.json");
    println!("\nwrote BENCH_attention.json");

    // CI perf-regression gate: in smoke mode the blocked kernel must not
    // be slower than the scalar oracle at the largest smoke size.
    if mode.is_smoke() && kernel_ok == Some(false) {
        eprintln!(
            "PERF REGRESSION: blocked flash kernel slower than the scalar \
             oracle at the largest smoke size — see BENCH_attention.json"
        );
        std::process::exit(1);
    }
    // fused-kernel gate (ROADMAP): at decode shapes the fused path must
    // be >= 1.5x over project-then-attend at the largest smoke window.
    if mode.is_smoke() && fused_ok == Some(false) {
        eprintln!(
            "PERF REGRESSION: fused SE(2)-Fourier kernel < 1.5x over \
             project-then-attend at the largest smoke window — see \
             BENCH_attention.json"
        );
        std::process::exit(1);
    }
    // observability gate: enabled tracing+profiling must cost <= 5% on
    // the kernel hot path (DESIGN.md §15 overhead budget)
    if mode.is_smoke() && overhead > 1.05 {
        eprintln!(
            "PERF REGRESSION: observability overhead {overhead:.3}x > 1.05x \
             on the blocked kernel — see BENCH_attention.json"
        );
        std::process::exit(1);
    }
    println!("attention_throughput OK");
}
