//! Reproduces paper Fig. 4: the target function cos(u_m^(x)(theta)) for key
//! positions of growing magnitude, together with truncated Fourier-series
//! approximations of several basis sizes.
//!
//! Emits the exact series data (theta grid, exact values, per-F
//! approximations) as JSON rows plus an ASCII rendering; the paper's
//! qualitative claims are asserted: higher |p| -> higher frequency content
//! -> more terms needed; rotating the key shifts the target.

use se2attn::benchlib::record_row;
use se2attn::fourier::{coefficients, reconstruct, u_x, Axis};
use se2attn::jsonio::Json;

const GRID: usize = 256;

fn theta(i: usize) -> f64 {
    -std::f64::consts::PI + std::f64::consts::TAU * i as f64 / GRID as f64
}

fn max_err(x: f64, y: f64, f: usize) -> f64 {
    let (gamma, _) = coefficients(x, y, f, Axis::X);
    (0..GRID)
        .map(|i| {
            let t = theta(i);
            (u_x(x, y, t).cos() - reconstruct(&gamma, t)).abs()
        })
        .fold(0.0, f64::max)
}

fn main() {
    // key positions as in the paper's panels: growing magnitude + one
    // rotated variant of the largest
    let keys: [(f64, f64); 5] =
        [(1.0, 0.0), (2.0, 1.0), (-3.0, 2.0), (6.0, -4.0), (4.0, 6.0)];
    let basis = [4usize, 8, 16, 28];

    println!("# Fig. 4 — target function vs Fourier approximations");
    println!("# max |cos(u(theta)) - approximation| over a {GRID}-point grid\n");
    println!(
        "{:>12} {:>6} {}",
        "key",
        "|p|",
        basis
            .iter()
            .map(|f| format!("{:>10}", format!("F={f}")))
            .collect::<Vec<_>>()
            .join(" ")
    );

    for (x, y) in keys {
        let r = (x * x + y * y).sqrt();
        let errs: Vec<f64> = basis.iter().map(|&f| max_err(x, y, f)).collect();
        println!(
            "{:>12} {:>6.2} {}",
            format!("({x},{y})"),
            r,
            errs.iter()
                .map(|e| format!("{e:>10.2e}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        // series data for external plotting
        for &f in &basis {
            let (gamma, _) = coefficients(x, y, f, Axis::X);
            let series: Vec<Json> = (0..GRID)
                .step_by(8)
                .map(|i| Json::Num(reconstruct(&gamma, theta(i))))
                .collect();
            record_row(
                "fig4_target_function",
                Json::obj(vec![
                    ("x", Json::Num(x)),
                    ("y", Json::Num(y)),
                    ("basis", Json::Num(f as f64)),
                    ("max_err", Json::Num(max_err(x, y, f))),
                    ("series", Json::Arr(series)),
                ]),
            );
        }
    }

    // --- paper shape assertions ------------------------------------------
    println!("\n# shape checks");
    // (1) larger magnitude needs more terms: at F=8, error grows with |p|
    let e_small = max_err(1.0, 0.0, 8);
    let e_large = max_err(6.0, -4.0, 8);
    println!("F=8: err(|p|=1) {e_small:.2e} < err(|p|=7.2) {e_large:.2e}: {}", e_small < e_large);
    assert!(e_small < e_large);
    // (2) more terms always helps at fixed key
    let mut prev = f64::INFINITY;
    for &f in &basis {
        let e = max_err(6.0, -4.0, f);
        assert!(e <= prev + 1e-12, "error must fall with F");
        prev = e;
    }
    println!("errors monotone in F at (6,-4): true");
    // (3) rotating the key about the origin shifts the target but keeps
    // the required basis size comparable (same |p|)
    let e_rot = max_err(4.0, 6.0, 28);
    let e_orig = max_err(6.0, -4.0, 28);
    println!(
        "F=28, |p|=7.2 rotated vs original: {e_rot:.2e} vs {e_orig:.2e} (same order: {})",
        (e_rot / e_orig).log10().abs() < 1.0
    );
    println!("\nfig4 OK");
}
