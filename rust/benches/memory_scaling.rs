//! The paper's headline claim: linear vs quadratic memory in the number of
//! scene tokens.
//!
//! Two measurements per N:
//! * **analytic** — the byte-accurate HBM model of `attention::memmodel`
//!   (what an fp16 GPU implementation materializes);
//! * **measured** — `peak_temp_bytes` actually allocated by the native
//!   Algorithm 1 / Algorithm 2 implementations on identical inputs.
//!
//! Expected shape: O(N) vs O(N^2) with a crossover in the hundreds of
//! tokens; beyond it the quadratic transient dominates and eventually
//! exceeds any fixed HBM budget while the linear path keeps scaling.

use se2attn::attention::memmodel::{
    crossover_n, linear_bytes, quadratic_bytes, BYTES_F16,
};
use se2attn::attention::{linear, quadratic, AttnProblem};
use se2attn::benchlib::{record_row, Table};
use se2attn::config::Method;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;

const D: usize = 48;
const F: usize = 12;

fn human(bytes: usize) -> String {
    if bytes > 1 << 30 {
        format!("{:.2} GiB", bytes as f64 / (1u64 << 30) as f64)
    } else if bytes > 1 << 20 {
        format!("{:.2} MiB", bytes as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1} KiB", bytes as f64 / 1024.0)
    }
}

fn main() {
    let full = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    println!("# Memory scaling — linear (Alg. 2) vs quadratic (Alg. 1)");
    println!("# d={D}, F={F}, fp16 analytic model; measured = native f32 impls\n");

    let mut table = Table::new(&[
        "N", "analytic quad", "analytic lin", "ratio", "measured quad", "measured lin",
    ]);

    let scales = [1.0, 0.5];
    let measure_cap = if full { 4096 } else { 1024 };
    for shift in 6..=13 {
        let n = 1usize << shift; // 64 .. 8192
        let aq = quadratic_bytes(n, n, D, BYTES_F16).transient_bytes;
        let al = linear_bytes(Method::Se2Fourier, n, n, D, F, BYTES_F16).transient_bytes;

        let (mq, ml) = if n <= measure_cap {
            let mut rng = Rng::new(n as u64);
            let q: Vec<f32> = (0..n * D).map(|_| rng.normal() as f32).collect();
            let poses: Vec<Pose> = (0..n)
                .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
                .collect();
            let tq: Vec<i32> = vec![0; n];
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d: D,
                fourier_f: F,
                scales: &scales,
                q: &q,
                k: &q,
                v: &q,
                pose_q: &poses,
                pose_k: &poses,
                tq: &tq,
                tk: &tq,
            };
            let ml = linear::attention(&p).peak_temp_bytes;
            // quadratic gets very slow past a few k tokens; that is the point
            let mq = if n <= 1024 || full {
                quadratic::attention(&p).peak_temp_bytes
            } else {
                0
            };
            (mq, ml)
        } else {
            (0, 0)
        };

        table.row(vec![
            n.to_string(),
            human(aq),
            human(al),
            format!("{:.1}x", aq as f64 / al as f64),
            if mq > 0 { human(mq) } else { "-".into() },
            if ml > 0 { human(ml) } else { "-".into() },
        ]);
        record_row(
            "memory_scaling",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("analytic_quadratic", Json::Num(aq as f64)),
                ("analytic_linear", Json::Num(al as f64)),
                ("measured_quadratic", Json::Num(mq as f64)),
                ("measured_linear", Json::Num(ml as f64)),
            ]),
        );
    }
    table.print();

    let cross = crossover_n(Method::Se2Fourier, D, F, BYTES_F16);
    println!("\ncrossover (analytic, self-attention): N = {cross}");
    println!("at N=8192 the quadratic transient is {} vs linear {} — {}x",
        human(quadratic_bytes(8192, 8192, D, BYTES_F16).transient_bytes),
        human(linear_bytes(Method::Se2Fourier, 8192, 8192, D, F, BYTES_F16).transient_bytes),
        quadratic_bytes(8192, 8192, D, BYTES_F16).transient_bytes
            / linear_bytes(Method::Se2Fourier, 8192, 8192, D, F, BYTES_F16).transient_bytes);

    // shape assertions
    let q1 = quadratic_bytes(1024, 1024, D, BYTES_F16).transient_bytes;
    let q2 = quadratic_bytes(2048, 2048, D, BYTES_F16).transient_bytes;
    assert_eq!(q2, 4 * q1, "quadratic must scale as N^2");
    let l1 = linear_bytes(Method::Se2Fourier, 1024, 1024, D, F, BYTES_F16).transient_bytes;
    let l2 = linear_bytes(Method::Se2Fourier, 2048, 2048, D, F, BYTES_F16).transient_bytes;
    assert!(l2 <= 2 * l1 + 1024, "linear must scale as N");
    println!("\nmemory_scaling OK (quadratic ~N^2, linear ~N confirmed)");
}
