//! Reproduces paper Fig. 3: spectral norm of the approximation error
//! ||phi(p_{n->m}) - phi_q(p_n) phi_k(p_m)||_2 as a function of key-position
//! radius, for several basis sizes, with 2.5/97.5 percentile bands and the
//! fp16/bf16 machine-epsilon reference lines.
//!
//! Expected shape (paper): error falls roughly exponentially in F and grows
//! with radius; F = 12 / 18 / 28 reach ~fp16 eps at radius 2 / 4 / 8; basis
//! size must grow ~50% per radius doubling to hold 1e-3.

use se2attn::benchlib::{percentile, record_row, Table};
use se2attn::fourier::{approximation_error, BF16_EPS, FP16_EPS};
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;

fn main() {
    let full = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    let samples = if full { 512 } else { 256 };
    let radii = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    let basis = [6usize, 12, 18, 28, 40];

    println!("# Fig. 3 — spectral-norm approximation error");
    println!("# {samples} samples per cell: key uniform on circle of given radius,");
    println!("# query heading uniform on [0, 2pi); f32 machine arithmetic is");
    println!("# emulated by f64 here (error floor ~1e-8 instead of ~1e-7).");
    println!("# fp16 eps = {FP16_EPS:.3e}, bf16 eps = {BF16_EPS:.3e}\n");

    let mut table = Table::new(&[
        "radius", "F", "mean", "p2.5", "p97.5", "<=fp16?", "<=bf16?",
    ]);

    for &r in &radii {
        for &f in &basis {
            let mut rng = Rng::new(0xF16_3 ^ (f as u64) << 8 ^ (r * 16.0) as u64);
            let mut errs: Vec<f64> = (0..samples)
                .map(|_| {
                    let psi = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
                    let pm = Pose::new(
                        r * psi.cos(),
                        r * psi.sin(),
                        rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                    );
                    // query at origin wlog (invariance proven elsewhere);
                    // heading uniform as in the paper
                    let pn = Pose::new(
                        0.0,
                        0.0,
                        rng.range(-std::f64::consts::PI, std::f64::consts::PI),
                    );
                    approximation_error(&pn, &pm, f)
                })
                .collect();
            errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mean = errs.iter().sum::<f64>() / errs.len() as f64;
            let lo = percentile(&errs, 2.5);
            let hi = percentile(&errs, 97.5);
            table.row(vec![
                format!("{r}"),
                format!("{f}"),
                format!("{mean:.3e}"),
                format!("{lo:.3e}"),
                format!("{hi:.3e}"),
                (mean <= FP16_EPS).to_string(),
                (mean <= BF16_EPS).to_string(),
            ]);
            record_row(
                "fig3_approx_error",
                Json::obj(vec![
                    ("radius", Json::Num(r)),
                    ("basis", Json::Num(f as f64)),
                    ("mean", Json::Num(mean)),
                    ("p2_5", Json::Num(lo)),
                    ("p97_5", Json::Num(hi)),
                ]),
            );
        }
    }
    table.print();

    // paper calibration checks (shape, not absolute):
    let check = |r: f64, f: usize| {
        let mut rng = Rng::new(1);
        let mut total = 0.0;
        for _ in 0..samples {
            let psi = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
            let pm = Pose::new(r * psi.cos(), r * psi.sin(), rng.range(-3.1, 3.1));
            let pn = Pose::new(0.0, 0.0, rng.range(-3.1, 3.1));
            total += approximation_error(&pn, &pm, f);
        }
        total / samples as f64
    };
    println!("\n# paper calibration: F=12@r=2, F=18@r=4, F=28@r=8 ~ fp16 eps");
    for (r, f) in [(2.0, 12), (4.0, 18), (8.0, 28)] {
        let e = check(r, f);
        println!(
            "F={f:>2} @ r={r}: mean {e:.3e}  ({})",
            if e < 3.0 * FP16_EPS { "matches paper band" } else { "OUTSIDE paper band" }
        );
    }
}
