//! Open-loop serving load bench: continuous batching vs the legacy
//! fixed-deadline batcher under Poisson arrivals (DESIGN.md §17).
//!
//! An open-loop generator submits rollout requests at a fixed offered
//! rate — arrivals never wait for completions, so overload actually
//! overloads the server instead of self-throttling.  Three load factors
//! (below / at / above the calibrated single-worker capacity) are run
//! through two schedulers over the same synthetic decode backend:
//!
//! - **continuous** — the real [`Server`]: per-shard admission queue
//!   with a queue-wait deadline, sessions join and leave the in-flight
//!   step batch every decode step, expired waiters are shed with a
//!   typed error instead of being served stale.
//! - **fixed** — the legacy [`Batcher`] driven the way the pre-refactor
//!   server drove it: one worker thread, deadline-flushed fixed batches,
//!   requests served whole and in order, binary queue-full rejection
//!   and no deadline shedding.
//!
//! Reported per (mode, rate): completion latency p50/p99/p999,
//! completed / shed / rejected counts, and **goodput** — completions
//! that met the end-to-end SLO, per second of wall time.  The headline
//! claim (and the CI smoke gate): at the overload point the continuous
//! scheduler sustains goodput >= the fixed batcher, because it spends
//! its capacity on requests that can still meet the SLO while the fixed
//! batcher burns it serving stale queue entries.
//!
//! Writes `BENCH_serving.json`; `bench-report` renders it into the
//! README "Serving under load" section.
//!
//! Run: `cargo bench --bench serving_load`
//! (CI smoke: `SE2ATTN_BENCH_SMOKE=1 cargo bench --bench serving_load`)

use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use se2attn::benchlib::{write_bench_json, BenchMode, Table};
use se2attn::config::{Method, ModelConfig, SimConfig, SystemConfig};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::coordinator::{
    AdmissionConfig, Backend, BackendFactory, Batcher, BatcherConfig, CacheConfig, KvCachePool,
    RolloutEngine, RolloutRequest, Router, ServeConfig, Server, SyntheticDecoder,
};
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::sim::ScenarioGenerator;

const METHOD: Method = Method::Se2Fourier;
/// Live sessions interleaved per decode step on the continuous path and
/// batch size on the fixed path — the same degree of batching for both.
const BATCH: usize = 4;
/// Bounded wait queue, identical for both schedulers.
const MAX_QUEUE: usize = 256;
/// Threads blocking on response channels; each records the completion
/// timestamp the moment its request resolves.
const COLLECTORS: usize = 8;

fn model_config() -> ModelConfig {
    ModelConfig::synthetic()
}

fn factory(work_per_token: usize) -> BackendFactory {
    Arc::new(move |_shard: usize| -> anyhow::Result<Backend> {
        let mut backend: Backend = Router::new();
        backend.deploy(
            METHOD,
            Box::new(SyntheticDecoder::with_work(
                model_config().n_actions,
                work_per_token,
            )),
        );
        Ok(backend)
    })
}

fn request(scenario: se2attn::sim::Scenario, sim: &SimConfig, seed: i32) -> RolloutRequest {
    RolloutRequest {
        scenario,
        t0: sim.history_steps - 1,
        n_samples: 1,
        temperature: 1.0,
        seed,
    }
}

// ---------------------------------------------------------------------------
// outcome accounting
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum Status {
    Done,
    Shed,
    Rejected,
    Failed,
}

#[derive(Clone, Copy)]
struct Outcome {
    latency_ms: f64,
    status: Status,
}

/// Per-(mode, rate) aggregate of an open-loop run.
struct RunStats {
    offered_rps: f64,
    goodput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    completed: usize,
    within_slo: usize,
    shed: usize,
    rejected: usize,
    failed: usize,
}

fn pctl(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx]
}

fn summarize(outcomes: &[Outcome], offered_rps: f64, wall: Duration, slo_ms: f64) -> RunStats {
    let mut done_ms: Vec<f64> = outcomes
        .iter()
        .filter(|o| o.status == Status::Done)
        .map(|o| o.latency_ms)
        .collect();
    done_ms.sort_by(|a, b| a.total_cmp(b));
    let within_slo = done_ms.iter().filter(|&&ms| ms <= slo_ms).count();
    let count = |s: Status| outcomes.iter().filter(|o| o.status == s).count();
    RunStats {
        offered_rps,
        goodput_rps: within_slo as f64 / wall.as_secs_f64().max(1e-9),
        p50_ms: pctl(&done_ms, 0.50),
        p99_ms: pctl(&done_ms, 0.99),
        p999_ms: pctl(&done_ms, 0.999),
        completed: done_ms.len(),
        within_slo,
        shed: count(Status::Shed),
        rejected: count(Status::Rejected),
        failed: count(Status::Failed),
    }
}

/// A submitted request waiting to be timed: submit timestamp plus the
/// response channel the scheduler will answer on.
type Pending = (Instant, mpsc::Receiver<anyhow::Result<se2attn::coordinator::RolloutResult>>);

/// Spawn the collector pool: threads pull pending requests as they are
/// submitted and block on each response channel, so every completion is
/// timestamped when it lands (not when a post-hoc drain reaches it).
fn spawn_collectors(
    jobs: mpsc::Receiver<Pending>,
) -> (Arc<Mutex<Vec<Outcome>>>, Vec<std::thread::JoinHandle<()>>) {
    let jobs = Arc::new(Mutex::new(jobs));
    let outcomes = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for _ in 0..COLLECTORS {
        let jobs = Arc::clone(&jobs);
        let outcomes = Arc::clone(&outcomes);
        handles.push(std::thread::spawn(move || loop {
            let job = jobs.lock().expect("collector queue").recv();
            let (submitted, rx) = match job {
                Ok(j) => j,
                Err(_) => break,
            };
            let res = rx.recv();
            let latency_ms = submitted.elapsed().as_secs_f64() * 1e3;
            let status = match res {
                Ok(Ok(_)) => Status::Done,
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    if msg.contains("shed") {
                        Status::Shed
                    } else if msg.contains("busy") {
                        Status::Rejected
                    } else {
                        Status::Failed
                    }
                }
                Err(_) => Status::Failed,
            };
            outcomes
                .lock()
                .expect("outcome sink")
                .push(Outcome { latency_ms, status });
        }));
    }
    (outcomes, handles)
}

/// Drive `submit` at `offered_rps` with exponential inter-arrival gaps
/// (Poisson process), never waiting on completions; returns the wall
/// time from first arrival to last collected outcome.
fn open_loop<F>(
    scenarios: Vec<se2attn::sim::Scenario>,
    sim: &SimConfig,
    offered_rps: f64,
    mut submit: F,
) -> (Vec<Outcome>, Duration)
where
    F: FnMut(RolloutRequest) -> Pending,
{
    let (jobs_tx, jobs_rx) = mpsc::channel();
    let (outcomes, handles) = spawn_collectors(jobs_rx);
    let mut rng = Rng::new(0x5e2a);
    let t0 = Instant::now();
    let mut next = t0;
    for (i, scenario) in scenarios.into_iter().enumerate() {
        let gap = -(1.0 - rng.uniform()).ln() / offered_rps;
        next += Duration::from_secs_f64(gap);
        if let Some(wait) = next.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let pending = submit(request(scenario, sim, i as i32));
        jobs_tx.send(pending).expect("collector pool alive");
    }
    drop(jobs_tx);
    for h in handles {
        h.join().expect("collector thread");
    }
    let wall = t0.elapsed();
    let outcomes = Arc::try_unwrap(outcomes)
        .map(|m| m.into_inner().expect("outcome sink"))
        .unwrap_or_default();
    (outcomes, wall)
}

// ---------------------------------------------------------------------------
// continuous mode: the real Server
// ---------------------------------------------------------------------------

fn run_continuous(
    scenarios: Vec<se2attn::sim::Scenario>,
    offered_rps: f64,
    deadline_ms: f64,
    slo_ms: f64,
    work_per_token: usize,
) -> RunStats {
    let sim = SimConfig::default();
    let cfg = SystemConfig {
        artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
        model: model_config(),
        sim: sim.clone(),
        threads: 1,
    };
    let server = Server::start_with_backend(
        cfg,
        vec![METHOD],
        ServeConfig {
            workers: 1,
            admission: AdmissionConfig {
                max_queue: MAX_QUEUE,
                deadline: Duration::from_secs_f64(deadline_ms / 1e3),
                max_live_sessions: BATCH,
                ..AdmissionConfig::default()
            },
            cache: CacheConfig::default(),
            kernel: se2attn::attention::kernel::KernelConfig::default(),
            ..ServeConfig::default()
        },
        factory(work_per_token),
    )
    .expect("server start");

    let (outcomes, wall) = open_loop(scenarios, &sim, offered_rps, |req| {
        let submitted = Instant::now();
        (submitted, server.submit(METHOD, req))
    });
    drop(server);
    summarize(&outcomes, offered_rps, wall, slo_ms)
}

// ---------------------------------------------------------------------------
// fixed mode: the legacy deadline-flushed batcher, pre-refactor shape
// ---------------------------------------------------------------------------

struct FixedJob {
    req: RolloutRequest,
    respond: mpsc::Sender<anyhow::Result<se2attn::coordinator::RolloutResult>>,
}

/// One worker thread around the legacy [`Batcher`]: recv until the
/// flush deadline, then serve the whole batch in order — exactly how
/// the server drove it before the continuous scheduler replaced it.
fn start_fixed(
    max_wait: Duration,
    work_per_token: usize,
) -> (mpsc::Sender<FixedJob>, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<FixedJob>();
    let handle = std::thread::spawn(move || {
        let model = model_config();
        let decoder = SyntheticDecoder::with_work(model.n_actions, work_per_token);
        let engine = RolloutEngine::new(model, SimConfig::default());
        let pool = KvCachePool::new(CacheConfig::default(), Arc::new(CacheStats::default()));
        let mut batcher: Batcher<FixedJob> = Batcher::new(BatcherConfig {
            batch_size: BATCH,
            max_wait,
            max_queue: MAX_QUEUE,
        });
        let serve = |batch: se2attn::coordinator::batcher::ReadyBatch<FixedJob>| {
            for job in batch.items {
                let res = engine.rollout_with_cache(&decoder, &job.req, &pool);
                let _ = job.respond.send(res);
            }
        };
        loop {
            let timeout = batcher
                .next_deadline(Instant::now())
                .unwrap_or(Duration::from_millis(5));
            match rx.recv_timeout(timeout) {
                Ok(job) => {
                    if let Err((job, err)) = batcher.push(job) {
                        let _ = job.respond.send(Err(anyhow::Error::new(err)));
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            while let Some(batch) = batcher.poll(Instant::now()) {
                serve(batch);
            }
        }
        for batch in batcher.drain() {
            serve(batch);
        }
    });
    (tx, handle)
}

fn run_fixed(
    scenarios: Vec<se2attn::sim::Scenario>,
    offered_rps: f64,
    max_wait: Duration,
    slo_ms: f64,
    work_per_token: usize,
) -> RunStats {
    let sim = SimConfig::default();
    let (tx, handle) = start_fixed(max_wait, work_per_token);
    let (outcomes, wall) = open_loop(scenarios, &sim, offered_rps, |req| {
        let (respond, rx) = mpsc::channel();
        let submitted = Instant::now();
        tx.send(FixedJob { req, respond }).expect("fixed worker alive");
        (submitted, rx)
    });
    drop(tx);
    handle.join().expect("fixed worker");
    summarize(&outcomes, offered_rps, wall, slo_ms)
}

// ---------------------------------------------------------------------------
// calibration + harness
// ---------------------------------------------------------------------------

/// Mean unloaded per-request service time (ms): the same rollout the
/// schedulers run, measured solo on this host so offered rates and SLO
/// scale with the machine instead of hard-coding milliseconds.
fn calibrate(work_per_token: usize, probes: usize) -> f64 {
    let sim = SimConfig::default();
    let model = model_config();
    let decoder = SyntheticDecoder::with_work(model.n_actions, work_per_token);
    let engine = RolloutEngine::new(model, sim.clone());
    let gen = ScenarioGenerator::new(sim.clone());
    let t0 = Instant::now();
    for i in 0..probes {
        let req = request(gen.generate(9_000 + i as u64), &sim, i as i32);
        engine.rollout(&decoder, &req).expect("calibration rollout");
    }
    (t0.elapsed().as_secs_f64() * 1e3 / probes as f64).max(0.05)
}

fn main() {
    let mode = BenchMode::from_env();
    let n_requests = *mode.pick(&[48usize], &[160], &[400]).first().unwrap();
    let load_factors: &[f64] = mode.pick(&[0.5, 2.5], &[0.5, 1.0, 2.5], &[0.5, 1.0, 1.5, 2.5]);
    let work_per_token = 48;
    let probes = *mode.pick(&[6usize], &[12], &[24]).first().unwrap();

    let base_ms = calibrate(work_per_token, probes);
    let capacity_rps = 1e3 / base_ms;
    // admitted requests share the step batch with up to BATCH peers, so
    // in-service latency inflates ~BATCH x over the solo service time;
    // the SLO budgets that plus a queue wait of the same order, and the
    // continuous scheduler sheds anything that waited longer than the
    // queue-wait budget (it could no longer meet the SLO anyway)
    let deadline_ms = 2.0 * base_ms;
    let slo_ms = deadline_ms + 2.0 * BATCH as f64 * base_ms;

    println!(
        "\n== serving load: open-loop Poisson arrivals, {n_requests} requests/rate, \
         1 worker, batch {BATCH} ==\n\
         calibrated solo service {base_ms:.2} ms -> capacity ~{capacity_rps:.0} rps, \
         SLO {slo_ms:.1} ms, queue-wait deadline {deadline_ms:.1} ms"
    );

    let sim = SimConfig::default();
    let gen = ScenarioGenerator::new(sim.clone());
    let mut table = Table::new(&[
        "mode",
        "load",
        "offered rps",
        "goodput rps",
        "p50 ms",
        "p99 ms",
        "p999 ms",
        "done",
        "in-SLO",
        "shed",
        "rej",
    ]);
    let mut rows = Vec::new();
    let mut overload_goodput: Option<(f64, f64)> = None; // (continuous, fixed)

    for &factor in load_factors {
        let offered = factor * capacity_rps;
        // same arrival schedule seed and scenario population for both
        // modes: the comparison differs only in the scheduler
        let scenarios: Vec<_> = (0..n_requests)
            .map(|i| gen.generate(3_000 + i as u64))
            .collect();
        let cont = run_continuous(
            scenarios.clone(),
            offered,
            deadline_ms,
            slo_ms,
            work_per_token,
        );
        let fixed = run_fixed(
            scenarios,
            offered,
            Duration::from_secs_f64(base_ms / 1e3),
            slo_ms,
            work_per_token,
        );
        for (name, r) in [("continuous", &cont), ("fixed", &fixed)] {
            assert_eq!(
                r.completed + r.shed + r.rejected + r.failed,
                n_requests,
                "{name}: every request must resolve exactly once"
            );
            assert_eq!(r.failed, 0, "{name}: no request may fail outright");
            table.row(vec![
                name.to_string(),
                format!("{factor:.1}x"),
                format!("{:.1}", r.offered_rps),
                format!("{:.1}", r.goodput_rps),
                format!("{:.1}", r.p50_ms),
                format!("{:.1}", r.p99_ms),
                format!("{:.1}", r.p999_ms),
                r.completed.to_string(),
                r.within_slo.to_string(),
                r.shed.to_string(),
                r.rejected.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("mode", Json::Str(name.to_string())),
                ("load_factor", Json::Num(factor)),
                ("offered_rps", Json::Num(r.offered_rps)),
                ("goodput_rps", Json::Num(r.goodput_rps)),
                ("p50_ms", Json::Num(r.p50_ms)),
                ("p99_ms", Json::Num(r.p99_ms)),
                ("p999_ms", Json::Num(r.p999_ms)),
                ("completed", Json::Num(r.completed as f64)),
                ("within_slo", Json::Num(r.within_slo as f64)),
                ("shed", Json::Num(r.shed as f64)),
                ("rejected", Json::Num(r.rejected as f64)),
                ("slo_ms", Json::Num(slo_ms)),
            ]));
        }
        overload_goodput = Some((cont.goodput_rps, fixed.goodput_rps));
    }
    table.print();

    write_bench_json("BENCH_serving.json", rows)
        .unwrap_or_else(|e| panic!("write BENCH_serving.json: {e}"));
    println!("wrote BENCH_serving.json (render: `se2-attention bench-report`)");

    // acceptance gate: at the overload point (last = highest factor) the
    // continuous scheduler must not lose goodput to the fixed batcher
    let (cont, fixed) = overload_goodput.expect("at least one load factor");
    println!(
        "overload goodput: continuous {cont:.1} rps vs fixed {fixed:.1} rps -> {}",
        if cont >= fixed { "PASS" } else { "FAIL" }
    );
    if cont < fixed {
        eprintln!(
            "continuous batching lost goodput to the fixed batcher under overload — \
             scheduler regression"
        );
        std::process::exit(1);
    }
}
