//! Ablation (paper Sec. V future work): invariant architecture vs
//! data-augmentation on a non-invariant one.
//!
//! Trains three configurations on identical data/budget:
//!   1. abs                      — non-invariant baseline
//!   2. abs + SE(2) frame jitter — the augmentation alternative
//!   3. se2fourier               — the paper's architectural invariance
//!
//! and evaluates NLL on (a) canonical robot-frame scenes and (b) scenes
//! presented in a randomly shifted global frame.  Expected shape: the
//! augmented model narrows the frame-shift generalization gap but the
//! invariant architecture closes it by construction (gap ~ Fourier
//! tolerance) at equal training budget.

use std::sync::Arc;

use se2attn::benchlib::{record_row, Table};
use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{ModelHandle, Trainer};
use se2attn::dataset::{augment_frame_jitter, collate, Example};
use se2attn::jsonio::Json;
use se2attn::metrics;
use se2attn::prng::Rng;
use se2attn::runtime::Engine;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn eval_nll(
    model: &ModelHandle,
    examples: &[Example],
    cfg: &SystemConfig,
    jitter: Option<u64>,
) -> anyhow::Result<f64> {
    let b = cfg.model.batch_size;
    let mut total = 0.0;
    let mut count = 0usize;
    let mut rng = jitter.map(Rng::new);
    for chunk in examples.chunks(b) {
        if chunk.len() < b {
            break;
        }
        let shifted: Vec<Example> = chunk
            .iter()
            .map(|e| match &mut rng {
                Some(r) => augment_frame_jitter(e, r, 2.0),
                None => e.clone(),
            })
            .collect();
        let refs: Vec<&Example> = shifted.iter().collect();
        let batch = collate(&refs);
        let logits = model.forward(&batch, cfg.model.n_tokens, cfg.model.feat_dim)?;
        let v = metrics::nll(&logits, &batch.target, cfg.model.n_actions);
        let labeled = batch.target.iter().filter(|&&t| t >= 0).count();
        total += v * labeled as f64;
        count += labeled;
    }
    Ok(total / count.max(1) as f64)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    let steps = env_usize("SE2ATTN_AB_STEPS", if full { 300 } else { 100 }) as u64;
    let n_examples = env_usize("SE2ATTN_AB_EXAMPLES", if full { 512 } else { 160 });

    let cfg = SystemConfig::load("artifacts")?;
    let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
    println!("# Ablation — architectural invariance vs data augmentation");
    println!("# {steps} steps, {n_examples} examples; eval NLL on canonical vs frame-shifted scenes\n");

    // held-out eval scenes, shared across configurations
    let tok = se2attn::tokenizer::Tokenizer::new(&cfg.model, &cfg.sim);
    let eval_examples =
        se2attn::dataset::generate_examples(&cfg.sim, &tok, 900_000, 48);

    let mut table = Table::new(&[
        "configuration", "NLL canonical", "NLL shifted-frame", "gap",
    ]);

    let configs: Vec<(&str, Method, Option<f64>)> = vec![
        ("abs (no augmentation)", Method::Abs, None),
        ("abs + SE(2) jitter augmentation", Method::Abs, Some(2.0)),
        ("se2fourier (invariant)", Method::Se2Fourier, None),
    ];

    for (label, method, augment) in configs {
        let mut model = ModelHandle::init(Arc::clone(&engine), method, 0)?;
        let mut trainer =
            Trainer::new(cfg.model.clone(), cfg.sim.clone(), n_examples, 7);
        trainer.augment = augment;
        trainer.run(&mut model, steps)?;
        let canon = eval_nll(&model, &eval_examples, &cfg, None)?;
        let shifted = eval_nll(&model, &eval_examples, &cfg, Some(5))?;
        let gap = shifted - canon;
        table.row(vec![
            label.into(),
            format!("{canon:.3}"),
            format!("{shifted:.3}"),
            format!("{gap:+.3}"),
        ]);
        record_row(
            "ablation_augmentation",
            Json::obj(vec![
                ("config", Json::Str(label.into())),
                ("nll_canonical", Json::Num(canon)),
                ("nll_shifted", Json::Num(shifted)),
                ("steps", Json::Num(steps as f64)),
            ]),
        );
    }
    println!();
    table.print();
    println!(
        "\n# expected shape: augmentation shrinks the abs gap; the invariant\n\
         # architecture's gap is ~0 by construction (Fourier tolerance)."
    );
    println!("\nablation_augmentation OK");
    Ok(())
}
