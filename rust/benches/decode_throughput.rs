//! Per-step decode latency: cached incremental decode vs full-window
//! recompute, across history-window sizes (DESIGN.md §10).
//!
//! Two layers are measured:
//!
//! 1. **Attention feature path** — one se2fourier head at the paper's
//!    d=48, F=12.  The full-recompute step re-projects every context token
//!    (Algorithm 2 from scratch); the cached step appends only the
//!    frontier rows to an [`IncrementalAttention`] engine, attends through
//!    the same flash kernel, and amortizes an SE(2) re-anchor every
//!    `REANCHOR_EVERY` steps to stay inside the |p| <= ~4 accuracy band.
//! 2. **Tokenization path** — full `tokenize_window` vs the serving
//!    [`KvCachePool`] hit path (frontier-only tokenization + exact pose
//!    re-anchor at emit).
//! 3. **Cache precision** — resident bytes of the same cached session
//!    population at f32 vs f16 (DESIGN.md §14).  In smoke mode this is a
//!    CI gate: the bench exits nonzero if f16 resident bytes exceed 60%
//!    of f32 at the largest smoke size.
//!
//! `--cache-precision f16|bf16` (after `cargo bench ... --`) runs the
//! cached attention path on a quantized feature cache and writes
//! `BENCH_decode_<precision>.json` instead of `BENCH_decode.json`, so
//! the CI perf-smoke job archives both tiers side by side.
//!
//! `--kernel-autotune` runs the one-shot startup microbenchmark
//! ([`KernelConfig::autotune`], env pins via `SE2ATTN_KERNEL_*` still
//! win) and benches both attention legs with the tuned
//! `{block_m, lanes, threads}` instead of the defaults — the same knob
//! `se2attn simulate --kernel-autotune` plumbs through `ServeConfig`.
//!
//! Expected shape: the cached step's projection cost is O(new tokens)
//! instead of O(window), so it wins for every window larger than the
//! frontier itself and the gap widens with the window; the acceptance
//! check prints per-row verdicts for window >= 32.

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::{linear, AttnProblem};
use se2attn::benchlib::{bench, record_row, write_bench_json, BenchMode, Table};
use se2attn::config::{CachePrecision, Method, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::sim::ScenarioGenerator;
use se2attn::tokenizer::Tokenizer;

const D: usize = 48;
const F: usize = 12;
/// Frontier tokens appended + queried per decode step.
const N_NEW: usize = 8;
/// Steps between cache re-anchors (drift re-centering).
const REANCHOR_EVERY: usize = 32;

struct Tokens {
    k: Vec<f32>,
    v: Vec<f32>,
    q: Vec<f32>,
    poses: Vec<Pose>,
    t: Vec<i32>,
}

fn tokens(rng: &mut Rng, n: usize, step: i32) -> Tokens {
    Tokens {
        k: (0..n * D).map(|_| rng.normal() as f32).collect(),
        v: (0..n * D).map(|_| rng.normal() as f32).collect(),
        q: (0..n * D).map(|_| rng.normal() as f32).collect(),
        poses: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        t: (0..n).map(|_| step).collect(),
    }
}

/// The model configuration both paths derive from (head shape matches
/// the paper's d=48, F=12; `kernel` is what `ServeConfig`/CLI plumb).
fn model_config(sim: &SimConfig) -> se2attn::config::ModelConfig {
    se2attn::config::ModelConfig {
        head_dim: D,
        fourier_f: F,
        n_tokens: sim.tokens_per_scene(),
        ..se2attn::config::ModelConfig::synthetic()
    }
}

/// Mode-scaled per-step timing loop (smoke keeps the CI gate quick).
fn step_bench<F: FnMut()>(mode: BenchMode, f: F) -> se2attn::benchlib::Stats {
    if mode.is_smoke() {
        bench(1, 8, std::time::Duration::from_millis(500), f)
    } else {
        bench(2, 30, std::time::Duration::from_secs(3), f)
    }
}

fn attention_path(
    mode: BenchMode,
    precision: CachePrecision,
    kernel: Option<KernelConfig>,
    rows: &mut Vec<Json>,
) {
    let mut model = model_config(&SimConfig::default());
    model.cache_precision = precision;
    if let Some(k) = kernel {
        // the autotuned shape reaches the cached engine the same way a
        // shard gets it: through ModelConfig.kernel
        model.kernel = k;
    }
    let scales = [1.0, 0.5, 0.25, 0.125];
    let sizes: &[usize] = mode.pick(
        &[16, 32, 64],
        &[16, 32, 64, 128, 256],
        &[16, 32, 64, 128, 256, 512, 1024],
    );
    let mut table = Table::new(&[
        "window",
        "full ms/step",
        "cached ms/step",
        "speedup",
        "window>=32 verdict",
    ]);
    println!(
        "== attention feature path: se2fourier d={D} F={F}, {N_NEW} frontier \
         tokens/step, re-anchor every {REANCHOR_EVERY} steps, cache \
         precision {} ==",
        precision.name()
    );
    for &m in sizes {
        let mut rng = Rng::new(m as u64 ^ 0xD15C);
        let ctx = tokens(&mut rng, m, 0);
        let new = tokens(&mut rng, N_NEW, 1);

        // ---- full recompute: Algorithm 2 over the whole window ----------
        let full = step_bench(mode, || {
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d: D,
                fourier_f: F,
                scales: &scales,
                q: &new.q,
                k: &ctx.k,
                v: &ctx.v,
                pose_q: &new.poses,
                pose_k: &ctx.poses,
                tq: &new.t,
                tk: &ctx.t,
            };
            let out = match kernel {
                Some(k) => linear::attention_with(&p, &k),
                None => linear::attention(&p),
            };
            std::hint::black_box(out.out);
        });

        // ---- cached: append frontier + attend, amortized re-anchor ------
        // the engine derives from ModelConfig, so the serving-layer
        // kernel knob reaches this path exactly as it does in a shard
        let mut eng = IncrementalAttention::new(IncrementalConfig::for_model(
            &model,
            Method::Se2Fourier,
        ));
        eng.append(&ctx.k, &ctx.v, &ctx.poses, &ctx.t);
        let mut step = 0usize;
        let drift = Pose::new(0.02, -0.01, 0.005);
        let cached = step_bench(mode, || {
            eng.evict_front(N_NEW);
            eng.append(&new.k, &new.v, &new.poses, &new.t);
            std::hint::black_box(eng.attend(&new.q, &new.poses, &new.t).out);
            step += 1;
            if step % REANCHOR_EVERY == 0 {
                eng.re_anchor(&drift).expect("se2fourier re-anchor");
            }
        });

        let speedup = full.mean_ms() / cached.mean_ms();
        let verdict = if m < 32 {
            "-".to_string()
        } else if speedup > 1.0 {
            "PASS (cached faster)".to_string()
        } else {
            format!("FAIL ({speedup:.2}x)")
        };
        table.row(vec![
            m.to_string(),
            format!("{:.3}", full.mean_ms()),
            format!("{:.3}", cached.mean_ms()),
            format!("{speedup:.2}x"),
            verdict,
        ]);
        let row = Json::obj(vec![
            ("path", Json::Str("attention".into())),
            ("precision", Json::Str(precision.name().into())),
            ("window", Json::Num(m as f64)),
            ("n_new", Json::Num(N_NEW as f64)),
            ("full", full.to_json()),
            ("cached", cached.to_json()),
            ("full_ms", Json::Num(full.mean_ms())),
            ("cached_ms", Json::Num(cached.mean_ms())),
            ("speedup", Json::Num(speedup)),
        ]);
        record_row("decode_throughput", row.clone());
        rows.push(row);
    }
    table.print();
}

fn tokenization_path(mode: BenchMode, rows: &mut Vec<Json>) {
    let sim = SimConfig::default();
    let model = model_config(&sim);
    let tok = Tokenizer::new(&model, &sim);
    let s = ScenarioGenerator::new(sim.clone()).generate(11);
    let h = sim.history_steps;
    let window: Vec<Vec<se2attn::sim::AgentState>> =
        (0..h).map(|t| s.states[t].clone()).collect();

    println!(
        "\n== tokenization path: {} map + {} agents x {} steps ==",
        sim.n_map_tokens, sim.n_agents, h
    );
    // Both paths slide the window every iteration, as a real rollout does
    // (pool.step's hit path advances the cached window by window.last(),
    // so calling it with an unchanged window would violate its contract).
    let slide = |w: &mut Vec<Vec<se2attn::sim::AgentState>>, t: &mut usize| {
        w.remove(0);
        w.push(s.states[*t % s.n_steps()].clone());
        *t += 1;
    };
    let tok_bench = |f: &mut dyn FnMut()| {
        if mode.is_smoke() {
            bench(2, 50, std::time::Duration::from_millis(500), f)
        } else {
            bench(5, 200, std::time::Duration::from_secs(2), f)
        }
    };
    let mut wf = window.clone();
    let mut tf = h;
    let full = tok_bench(&mut || {
        std::hint::black_box(tok.tokenize_window(&s.map_elements, &wf, None));
        slide(&mut wf, &mut tf);
    });

    let pool = KvCachePool::new(
        CacheConfig::default(),
        std::sync::Arc::new(CacheStats::default()),
    );
    let key = SessionKey { scene: s.seed, t0: h as u32 - 1, sample: 0 };
    let mut wc = window.clone();
    let mut tc = h;
    pool.step(key, &tok, &s.map_elements, &wc).unwrap(); // warm (miss)
    slide(&mut wc, &mut tc);
    let cached = tok_bench(&mut || {
        std::hint::black_box(pool.step(key, &tok, &s.map_elements, &wc).unwrap());
        slide(&mut wc, &mut tc);
    });
    let speedup = full.mean_ns / cached.mean_ns;
    let mut table = Table::new(&["path", "us/step", "speedup"]);
    table.row(vec!["full tokenize_window".into(), format!("{:.1}", full.mean_ns / 1e3), "1.00x".into()]);
    table.row(vec!["cached pool.step (hit)".into(), format!("{:.1}", cached.mean_ns / 1e3), format!("{speedup:.2}x")]);
    table.print();
    let row = Json::obj(vec![
        ("path", Json::Str("tokenization".into())),
        ("full", full.to_json()),
        ("cached", cached.to_json()),
        ("full_us", Json::Num(full.mean_ns / 1e3)),
        ("cached_us", Json::Num(cached.mean_ns / 1e3)),
        ("speedup", Json::Num(speedup)),
    ]);
    record_row("decode_throughput", row.clone());
    rows.push(row);
}

/// Resident bytes of the same cached session population at f32 vs f16:
/// the serving capacity claim of DESIGN.md §14 in measured (not modeled)
/// bytes, with the CI gate at the largest size.  Returns `false` when
/// the gate fails.
fn cache_precision_section(mode: BenchMode, rows: &mut Vec<Json>) -> bool {
    let model = model_config(&SimConfig::default());
    let sizes: &[usize] = mode.pick(&[16, 32, 64], &[16, 64, 256], &[16, 64, 256, 1024]);
    println!("\n== cache precision: resident bytes of an m-row se2fourier feature cache ==");
    let mut table = Table::new(&["window", "f32 bytes", "f16 bytes", "f16/f32", "gate (<=60%)"]);
    let mut ok = true;
    for (idx, &m) in sizes.iter().enumerate() {
        let mut rng = Rng::new(m as u64 ^ 0xBEEF);
        let ctx = tokens(&mut rng, m, 0);
        let bytes_at = |precision: CachePrecision| -> usize {
            let mut cfg = model.clone();
            cfg.cache_precision = precision;
            let mut eng = IncrementalAttention::new(IncrementalConfig::for_model(
                &cfg,
                Method::Se2Fourier,
            ));
            eng.append(&ctx.k, &ctx.v, &ctx.poses, &ctx.t);
            eng.resident_bytes()
        };
        let f32_bytes = bytes_at(CachePrecision::F32);
        let f16_bytes = bytes_at(CachePrecision::F16);
        let ratio = f16_bytes as f64 / f32_bytes as f64;
        // the gate applies at the largest size of the sweep
        let gated = idx == sizes.len() - 1;
        let pass = ratio <= 0.60;
        if gated && !pass {
            ok = false;
        }
        table.row(vec![
            m.to_string(),
            f32_bytes.to_string(),
            f16_bytes.to_string(),
            format!("{:.0}%", ratio * 100.0),
            if !gated {
                "-".into()
            } else if pass {
                "PASS".into()
            } else {
                format!("FAIL ({:.0}% > 60%)", ratio * 100.0)
            },
        ]);
        let row = Json::obj(vec![
            ("path", Json::Str("cache_precision".into())),
            ("window", Json::Num(m as f64)),
            ("f32_bytes", Json::Num(f32_bytes as f64)),
            ("f16_bytes", Json::Num(f16_bytes as f64)),
            ("ratio", Json::Num(ratio)),
        ]);
        record_row("decode_throughput", row.clone());
        rows.push(row);
    }
    table.print();
    ok
}

fn main() {
    let mode = BenchMode::from_env();
    // `cargo bench --bench decode_throughput -- --cache-precision f16`
    let mut precision = CachePrecision::F32;
    let mut autotune = false;
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        if a == "--cache-precision" {
            let v = args.get(i + 1).expect("--cache-precision needs a value");
            precision = CachePrecision::parse(v).expect("bad --cache-precision");
        } else if let Some(v) = a.strip_prefix("--cache-precision=") {
            precision = CachePrecision::parse(v).expect("bad --cache-precision");
        } else if a == "--kernel-autotune" {
            autotune = true;
        }
    }
    let kernel = if autotune {
        let k = KernelConfig::autotune();
        println!(
            "kernel autotune: block_m={} lanes={} threads={}\n",
            k.block_m, k.lanes, k.threads
        );
        Some(k)
    } else {
        None
    };
    let mut rows: Vec<Json> = Vec::new();
    attention_path(mode, precision, kernel, &mut rows);
    tokenization_path(mode, &mut rows);
    let bytes_ok = cache_precision_section(mode, &mut rows);
    let out = match precision {
        CachePrecision::F32 => "BENCH_decode.json".to_string(),
        p => format!("BENCH_decode_{}.json", p.name()),
    };
    write_bench_json(&out, rows).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("\nwrote {out}");
    if mode.is_smoke() && !bytes_ok {
        eprintln!(
            "perf-smoke gate: f16 resident cache bytes exceed 60% of f32 \
             at the largest smoke size"
        );
        std::process::exit(1);
    }
}
