//! Per-step decode latency: cached incremental decode vs full-window
//! recompute, across history-window sizes (DESIGN.md §10).
//!
//! Two layers are measured:
//!
//! 1. **Attention feature path** — one se2fourier head at the paper's
//!    d=48, F=12.  The full-recompute step re-projects every context token
//!    (Algorithm 2 from scratch); the cached step appends only the
//!    frontier rows to an [`IncrementalAttention`] engine, attends through
//!    the same flash kernel, and amortizes an SE(2) re-anchor every
//!    `REANCHOR_EVERY` steps to stay inside the |p| <= ~4 accuracy band.
//! 2. **Tokenization path** — full `tokenize_window` vs the serving
//!    [`KvCachePool`] hit path (frontier-only tokenization + exact pose
//!    re-anchor at emit).
//!
//! Expected shape: the cached step's projection cost is O(new tokens)
//! instead of O(window), so it wins for every window larger than the
//! frontier itself and the gap widens with the window; the acceptance
//! check prints per-row verdicts for window >= 32.

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::{linear, AttnProblem};
use se2attn::benchlib::{bench, record_row, Table};
use se2attn::config::{Method, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::sim::ScenarioGenerator;
use se2attn::tokenizer::Tokenizer;

const D: usize = 48;
const F: usize = 12;
/// Frontier tokens appended + queried per decode step.
const N_NEW: usize = 8;
/// Steps between cache re-anchors (drift re-centering).
const REANCHOR_EVERY: usize = 32;

struct Tokens {
    k: Vec<f32>,
    v: Vec<f32>,
    q: Vec<f32>,
    poses: Vec<Pose>,
    t: Vec<i32>,
}

fn tokens(rng: &mut Rng, n: usize, step: i32) -> Tokens {
    Tokens {
        k: (0..n * D).map(|_| rng.normal() as f32).collect(),
        v: (0..n * D).map(|_| rng.normal() as f32).collect(),
        q: (0..n * D).map(|_| rng.normal() as f32).collect(),
        poses: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        t: (0..n).map(|_| step).collect(),
    }
}

fn attention_path(full_mode: bool) {
    let scales = [1.0, 0.5, 0.25, 0.125];
    let sizes: &[usize] = if full_mode {
        &[16, 32, 64, 128, 256, 512, 1024]
    } else {
        &[16, 32, 64, 128, 256]
    };
    let mut table = Table::new(&[
        "window",
        "full ms/step",
        "cached ms/step",
        "speedup",
        "window>=32 verdict",
    ]);
    println!(
        "== attention feature path: se2fourier d={D} F={F}, {N_NEW} frontier \
         tokens/step, re-anchor every {REANCHOR_EVERY} steps =="
    );
    for &m in sizes {
        let mut rng = Rng::new(m as u64 ^ 0xD15C);
        let ctx = tokens(&mut rng, m, 0);
        let new = tokens(&mut rng, N_NEW, 1);

        // ---- full recompute: Algorithm 2 over the whole window ----------
        let full = bench(2, 30, std::time::Duration::from_secs(3), || {
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d: D,
                fourier_f: F,
                scales: &scales,
                q: &new.q,
                k: &ctx.k,
                v: &ctx.v,
                pose_q: &new.poses,
                pose_k: &ctx.poses,
                tq: &new.t,
                tk: &ctx.t,
            };
            std::hint::black_box(linear::attention(&p).out);
        });

        // ---- cached: append frontier + attend, amortized re-anchor ------
        let mut eng = IncrementalAttention::new(IncrementalConfig {
            method: Method::Se2Fourier,
            d: D,
            fourier_f: F,
            scales: scales.to_vec(),
        });
        eng.append(&ctx.k, &ctx.v, &ctx.poses, &ctx.t);
        let mut step = 0usize;
        let drift = Pose::new(0.02, -0.01, 0.005);
        let cached = bench(2, 30, std::time::Duration::from_secs(3), || {
            eng.evict_front(N_NEW);
            eng.append(&new.k, &new.v, &new.poses, &new.t);
            std::hint::black_box(eng.attend(&new.q, &new.poses, &new.t).out);
            step += 1;
            if step % REANCHOR_EVERY == 0 {
                eng.re_anchor(&drift).expect("se2fourier re-anchor");
            }
        });

        let speedup = full.mean_ms() / cached.mean_ms();
        let verdict = if m < 32 {
            "-".to_string()
        } else if speedup > 1.0 {
            "PASS (cached faster)".to_string()
        } else {
            format!("FAIL ({speedup:.2}x)")
        };
        table.row(vec![
            m.to_string(),
            format!("{:.3}", full.mean_ms()),
            format!("{:.3}", cached.mean_ms()),
            format!("{speedup:.2}x"),
            verdict,
        ]);
        record_row(
            "decode_throughput",
            Json::obj(vec![
                ("path", Json::Str("attention".into())),
                ("window", Json::Num(m as f64)),
                ("n_new", Json::Num(N_NEW as f64)),
                ("full_ms", Json::Num(full.mean_ms())),
                ("cached_ms", Json::Num(cached.mean_ms())),
                ("speedup", Json::Num(speedup)),
            ]),
        );
    }
    table.print();
}

fn tokenization_path() {
    let sim = SimConfig::default();
    let model = se2attn::config::ModelConfig {
        n_layers: 2,
        n_heads: 2,
        head_dim: D,
        d_model: 96,
        d_ff: 192,
        n_tokens: sim.tokens_per_scene(),
        feat_dim: 16,
        n_actions: 64,
        fourier_f: F,
        spatial_scales: vec![1.0, 0.5, 0.25, 0.125],
        batch_size: 8,
        learning_rate: 3e-4,
        map_timestep: -1,
        param_names: vec![],
    };
    let tok = Tokenizer::new(&model, &sim);
    let s = ScenarioGenerator::new(sim.clone()).generate(11);
    let h = sim.history_steps;
    let window: Vec<Vec<se2attn::sim::AgentState>> =
        (0..h).map(|t| s.states[t].clone()).collect();

    println!(
        "\n== tokenization path: {} map + {} agents x {} steps ==",
        sim.n_map_tokens, sim.n_agents, h
    );
    // Both paths slide the window every iteration, as a real rollout does
    // (pool.step's hit path advances the cached window by window.last(),
    // so calling it with an unchanged window would violate its contract).
    let slide = |w: &mut Vec<Vec<se2attn::sim::AgentState>>, t: &mut usize| {
        w.remove(0);
        w.push(s.states[*t % s.n_steps()].clone());
        *t += 1;
    };
    let mut wf = window.clone();
    let mut tf = h;
    let full = bench(5, 200, std::time::Duration::from_secs(2), || {
        std::hint::black_box(tok.tokenize_window(&s.map_elements, &wf, None));
        slide(&mut wf, &mut tf);
    });

    let pool = KvCachePool::new(
        CacheConfig::default(),
        std::sync::Arc::new(CacheStats::default()),
    );
    let key = SessionKey { scene: s.seed, t0: h as u32 - 1, sample: 0 };
    let mut wc = window.clone();
    let mut tc = h;
    pool.step(key, &tok, &s.map_elements, &wc).unwrap(); // warm (miss)
    slide(&mut wc, &mut tc);
    let cached = bench(5, 200, std::time::Duration::from_secs(2), || {
        std::hint::black_box(pool.step(key, &tok, &s.map_elements, &wc).unwrap());
        slide(&mut wc, &mut tc);
    });
    let speedup = full.mean_ns / cached.mean_ns;
    let mut table = Table::new(&["path", "us/step", "speedup"]);
    table.row(vec!["full tokenize_window".into(), format!("{:.1}", full.mean_ns / 1e3), "1.00x".into()]);
    table.row(vec!["cached pool.step (hit)".into(), format!("{:.1}", cached.mean_ns / 1e3), format!("{speedup:.2}x")]);
    table.print();
    record_row(
        "decode_throughput",
        Json::obj(vec![
            ("path", Json::Str("tokenization".into())),
            ("full_us", Json::Num(full.mean_ns / 1e3)),
            ("cached_us", Json::Num(cached.mean_ns / 1e3)),
            ("speedup", Json::Num(speedup)),
        ]),
    );
}

fn main() {
    let full_mode = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    attention_path(full_mode);
    tokenization_path();
}
