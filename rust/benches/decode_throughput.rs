//! Per-step decode latency: cached incremental decode vs full-window
//! recompute, across history-window sizes (DESIGN.md §10).
//!
//! Two layers are measured:
//!
//! 1. **Attention feature path** — one se2fourier head at the paper's
//!    d=48, F=12.  The full-recompute step re-projects every context token
//!    (Algorithm 2 from scratch); the cached step appends only the
//!    frontier rows to an [`IncrementalAttention`] engine, attends through
//!    the same flash kernel, and amortizes an SE(2) re-anchor every
//!    `REANCHOR_EVERY` steps to stay inside the |p| <= ~4 accuracy band.
//! 2. **Tokenization path** — full `tokenize_window` vs the serving
//!    [`KvCachePool`] hit path (frontier-only tokenization + exact pose
//!    re-anchor at emit).
//!
//! Expected shape: the cached step's projection cost is O(new tokens)
//! instead of O(window), so it wins for every window larger than the
//! frontier itself and the gap widens with the window; the acceptance
//! check prints per-row verdicts for window >= 32.

use se2attn::attention::incremental::{IncrementalAttention, IncrementalConfig};
use se2attn::attention::kernel::KernelConfig;
use se2attn::attention::{linear, AttnProblem};
use se2attn::benchlib::{bench, record_row, write_bench_json, BenchMode, Table};
use se2attn::config::{Method, SimConfig};
use se2attn::coordinator::kvcache::{CacheConfig, KvCachePool, SessionKey};
use se2attn::coordinator::telemetry::CacheStats;
use se2attn::geometry::Pose;
use se2attn::jsonio::Json;
use se2attn::prng::Rng;
use se2attn::sim::ScenarioGenerator;
use se2attn::tokenizer::Tokenizer;

const D: usize = 48;
const F: usize = 12;
/// Frontier tokens appended + queried per decode step.
const N_NEW: usize = 8;
/// Steps between cache re-anchors (drift re-centering).
const REANCHOR_EVERY: usize = 32;

struct Tokens {
    k: Vec<f32>,
    v: Vec<f32>,
    q: Vec<f32>,
    poses: Vec<Pose>,
    t: Vec<i32>,
}

fn tokens(rng: &mut Rng, n: usize, step: i32) -> Tokens {
    Tokens {
        k: (0..n * D).map(|_| rng.normal() as f32).collect(),
        v: (0..n * D).map(|_| rng.normal() as f32).collect(),
        q: (0..n * D).map(|_| rng.normal() as f32).collect(),
        poses: (0..n)
            .map(|_| Pose::new(rng.range(-2.0, 2.0), rng.range(-2.0, 2.0), rng.range(-3.1, 3.1)))
            .collect(),
        t: (0..n).map(|_| step).collect(),
    }
}

/// The model configuration both paths derive from (head shape matches
/// the paper's d=48, F=12; `kernel` is what `ServeConfig`/CLI plumb).
fn model_config(sim: &SimConfig) -> se2attn::config::ModelConfig {
    se2attn::config::ModelConfig {
        n_layers: 2,
        n_heads: 2,
        head_dim: D,
        d_model: 96,
        d_ff: 192,
        n_tokens: sim.tokens_per_scene(),
        feat_dim: 16,
        n_actions: 64,
        fourier_f: F,
        spatial_scales: vec![1.0, 0.5, 0.25, 0.125],
        batch_size: 8,
        learning_rate: 3e-4,
        map_timestep: -1,
        param_names: vec![],
        kernel: KernelConfig::default(),
    }
}

/// Mode-scaled per-step timing loop (smoke keeps the CI gate quick).
fn step_bench<F: FnMut()>(mode: BenchMode, f: F) -> se2attn::benchlib::Stats {
    if mode.is_smoke() {
        bench(1, 8, std::time::Duration::from_millis(500), f)
    } else {
        bench(2, 30, std::time::Duration::from_secs(3), f)
    }
}

fn attention_path(mode: BenchMode, rows: &mut Vec<Json>) {
    let model = model_config(&SimConfig::default());
    let scales = [1.0, 0.5, 0.25, 0.125];
    let sizes: &[usize] = mode.pick(
        &[16, 32, 64],
        &[16, 32, 64, 128, 256],
        &[16, 32, 64, 128, 256, 512, 1024],
    );
    let mut table = Table::new(&[
        "window",
        "full ms/step",
        "cached ms/step",
        "speedup",
        "window>=32 verdict",
    ]);
    println!(
        "== attention feature path: se2fourier d={D} F={F}, {N_NEW} frontier \
         tokens/step, re-anchor every {REANCHOR_EVERY} steps =="
    );
    for &m in sizes {
        let mut rng = Rng::new(m as u64 ^ 0xD15C);
        let ctx = tokens(&mut rng, m, 0);
        let new = tokens(&mut rng, N_NEW, 1);

        // ---- full recompute: Algorithm 2 over the whole window ----------
        let full = step_bench(mode, || {
            let p = AttnProblem {
                method: Method::Se2Fourier,
                d: D,
                fourier_f: F,
                scales: &scales,
                q: &new.q,
                k: &ctx.k,
                v: &ctx.v,
                pose_q: &new.poses,
                pose_k: &ctx.poses,
                tq: &new.t,
                tk: &ctx.t,
            };
            std::hint::black_box(linear::attention(&p).out);
        });

        // ---- cached: append frontier + attend, amortized re-anchor ------
        // the engine derives from ModelConfig, so the serving-layer
        // kernel knob reaches this path exactly as it does in a shard
        let mut eng = IncrementalAttention::new(IncrementalConfig::for_model(
            &model,
            Method::Se2Fourier,
        ));
        eng.append(&ctx.k, &ctx.v, &ctx.poses, &ctx.t);
        let mut step = 0usize;
        let drift = Pose::new(0.02, -0.01, 0.005);
        let cached = step_bench(mode, || {
            eng.evict_front(N_NEW);
            eng.append(&new.k, &new.v, &new.poses, &new.t);
            std::hint::black_box(eng.attend(&new.q, &new.poses, &new.t).out);
            step += 1;
            if step % REANCHOR_EVERY == 0 {
                eng.re_anchor(&drift).expect("se2fourier re-anchor");
            }
        });

        let speedup = full.mean_ms() / cached.mean_ms();
        let verdict = if m < 32 {
            "-".to_string()
        } else if speedup > 1.0 {
            "PASS (cached faster)".to_string()
        } else {
            format!("FAIL ({speedup:.2}x)")
        };
        table.row(vec![
            m.to_string(),
            format!("{:.3}", full.mean_ms()),
            format!("{:.3}", cached.mean_ms()),
            format!("{speedup:.2}x"),
            verdict,
        ]);
        let row = Json::obj(vec![
            ("path", Json::Str("attention".into())),
            ("window", Json::Num(m as f64)),
            ("n_new", Json::Num(N_NEW as f64)),
            ("full", full.to_json()),
            ("cached", cached.to_json()),
            ("full_ms", Json::Num(full.mean_ms())),
            ("cached_ms", Json::Num(cached.mean_ms())),
            ("speedup", Json::Num(speedup)),
        ]);
        record_row("decode_throughput", row.clone());
        rows.push(row);
    }
    table.print();
}

fn tokenization_path(mode: BenchMode, rows: &mut Vec<Json>) {
    let sim = SimConfig::default();
    let model = model_config(&sim);
    let tok = Tokenizer::new(&model, &sim);
    let s = ScenarioGenerator::new(sim.clone()).generate(11);
    let h = sim.history_steps;
    let window: Vec<Vec<se2attn::sim::AgentState>> =
        (0..h).map(|t| s.states[t].clone()).collect();

    println!(
        "\n== tokenization path: {} map + {} agents x {} steps ==",
        sim.n_map_tokens, sim.n_agents, h
    );
    // Both paths slide the window every iteration, as a real rollout does
    // (pool.step's hit path advances the cached window by window.last(),
    // so calling it with an unchanged window would violate its contract).
    let slide = |w: &mut Vec<Vec<se2attn::sim::AgentState>>, t: &mut usize| {
        w.remove(0);
        w.push(s.states[*t % s.n_steps()].clone());
        *t += 1;
    };
    let tok_bench = |f: &mut dyn FnMut()| {
        if mode.is_smoke() {
            bench(2, 50, std::time::Duration::from_millis(500), f)
        } else {
            bench(5, 200, std::time::Duration::from_secs(2), f)
        }
    };
    let mut wf = window.clone();
    let mut tf = h;
    let full = tok_bench(&mut || {
        std::hint::black_box(tok.tokenize_window(&s.map_elements, &wf, None));
        slide(&mut wf, &mut tf);
    });

    let pool = KvCachePool::new(
        CacheConfig::default(),
        std::sync::Arc::new(CacheStats::default()),
    );
    let key = SessionKey { scene: s.seed, t0: h as u32 - 1, sample: 0 };
    let mut wc = window.clone();
    let mut tc = h;
    pool.step(key, &tok, &s.map_elements, &wc).unwrap(); // warm (miss)
    slide(&mut wc, &mut tc);
    let cached = tok_bench(&mut || {
        std::hint::black_box(pool.step(key, &tok, &s.map_elements, &wc).unwrap());
        slide(&mut wc, &mut tc);
    });
    let speedup = full.mean_ns / cached.mean_ns;
    let mut table = Table::new(&["path", "us/step", "speedup"]);
    table.row(vec!["full tokenize_window".into(), format!("{:.1}", full.mean_ns / 1e3), "1.00x".into()]);
    table.row(vec!["cached pool.step (hit)".into(), format!("{:.1}", cached.mean_ns / 1e3), format!("{speedup:.2}x")]);
    table.print();
    let row = Json::obj(vec![
        ("path", Json::Str("tokenization".into())),
        ("full", full.to_json()),
        ("cached", cached.to_json()),
        ("full_us", Json::Num(full.mean_ns / 1e3)),
        ("cached_us", Json::Num(cached.mean_ns / 1e3)),
        ("speedup", Json::Num(speedup)),
    ]);
    record_row("decode_throughput", row.clone());
    rows.push(row);
}

fn main() {
    let mode = BenchMode::from_env();
    let mut rows: Vec<Json> = Vec::new();
    attention_path(mode, &mut rows);
    tokenization_path(mode, &mut rows);
    write_bench_json("BENCH_decode.json", rows).expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");
}
