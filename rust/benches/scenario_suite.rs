//! Scenario-suite benchmark: per-family generation + tokenization
//! throughput, plus a composition snapshot (agent kinds, trajectory
//! classes) so regressions in world richness are visible next to the
//! timing numbers.

use se2attn::benchlib::{bench_quick, record_row, Table};
use se2attn::config::{ModelConfig, SimConfig};
use se2attn::jsonio::Json;
use se2attn::sim::suite::registry;
use se2attn::sim::AgentKind;
use se2attn::tokenizer::Tokenizer;

fn model_config() -> ModelConfig {
    ModelConfig::synthetic()
}

fn main() {
    let sim = SimConfig::default();
    let tok = Tokenizer::new(&model_config(), &sim);
    let mut table = Table::new(&[
        "family", "gen ms", "tokenize ms", "lanes", "V/P/C", "classes",
    ]);

    for fam in registry() {
        // standalone path: each family's own advisory agent-count knob
        // (the model-serving path pins the count to SimConfig::n_agents)
        let n_agents = fam.knobs.n_agents;
        let mut seed = 0u64;
        let gen_stats = bench_quick(|| {
            let s = fam.generate_n(&sim, n_agents, seed);
            seed = seed.wrapping_add(1);
            std::hint::black_box(s.n_steps());
        });

        let s = fam.generate_n(&sim, n_agents, 1);
        let tok_stats = bench_quick(|| {
            let ts = tok.tokenize_scenario(&s, sim.history_steps - 1);
            std::hint::black_box(ts.feat.len());
        });

        let mut kinds = [0usize; 3];
        for a in &s.states[0] {
            match a.kind {
                AgentKind::Vehicle => kinds[0] += 1,
                AgentKind::Pedestrian => kinds[1] += 1,
                AgentKind::Cyclist => kinds[2] += 1,
            }
        }
        let mut classes = std::collections::BTreeSet::new();
        for a in 0..s.n_agents() {
            classes.insert(s.classify_future(a, sim.history_steps - 1).name());
        }
        let class_list: Vec<&str> = classes.into_iter().collect();

        table.row(vec![
            fam.id.name().to_string(),
            format!("{:.3}", gen_stats.mean_ms()),
            format!("{:.4}", tok_stats.mean_ms()),
            format!("{}", s.map.lanes.len()),
            format!("{}/{}/{}", kinds[0], kinds[1], kinds[2]),
            class_list.join("+"),
        ]);
        record_row(
            "scenario_suite",
            Json::obj(vec![
                ("family", Json::Str(fam.id.name().to_string())),
                ("gen", gen_stats.to_json()),
                ("tokenize", tok_stats.to_json()),
                ("lanes", Json::Num(s.map.lanes.len() as f64)),
            ]),
        );
    }
    println!("scenario suite: generation + tokenization per family");
    table.print();
}
