//! Reproduces paper Table I: agent-simulation metrics (NLL + minADE by
//! trajectory class) for the four attention methods, averaged over seeds.
//!
//! Full pipeline per (method, seed): init params -> train on the synthetic
//! scenario dataset -> evaluate NLL on held-out scenes -> sampled rollouts
//! -> minADE split into stationary / straight / turning.
//!
//! Expected *shape* (paper): 2D RoPE ~ SE(2) Fourier <= SE(2) Rep <
//! AbsPos on NLL; SE(2) Fourier best on the turning class.  Absolute
//! numbers differ (tiny model, synthetic data, CPU) — orderings are the
//! reproduction target.
//!
//! Knobs (env): SE2ATTN_T1_STEPS / _SEEDS / _SCENES / _SAMPLES / _EXAMPLES,
//! SE2ATTN_BENCH_FULL=1 selects the heavier defaults.

use std::sync::Arc;

use se2attn::benchlib::{record_row, Table};
use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{ModelHandle, RolloutEngine, Trainer};
use se2attn::jsonio::Json;
use se2attn::metrics::{mean_std, TableOneRow};
use se2attn::runtime::Engine;
use se2attn::sim::TrajectoryClass;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("SE2ATTN_BENCH_FULL").is_ok();
    let steps = env_usize("SE2ATTN_T1_STEPS", if full { 300 } else { 120 }) as u64;
    let n_seeds = env_usize("SE2ATTN_T1_SEEDS", if full { 3 } else { 2 });
    let n_scenes = env_usize("SE2ATTN_T1_SCENES", if full { 16 } else { 6 });
    let n_samples = env_usize("SE2ATTN_T1_SAMPLES", if full { 16 } else { 8 });
    let n_examples = env_usize("SE2ATTN_T1_EXAMPLES", if full { 512 } else { 192 });

    let cfg = SystemConfig::load("artifacts")?;
    println!("# Table I — agent simulation ({n_seeds} seeds x {steps} steps, ");
    println!("#           {n_scenes} eval scenes x {n_samples} rollout samples, {n_examples} train examples)\n");

    let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
    let rollout = RolloutEngine::new(cfg.model.clone(), cfg.sim.clone());
    let eval_seeds: Vec<u64> = (20_000..20_000 + n_scenes as u64).collect();

    let mut table = Table::new(&[
        "Attention Method", "NLL", "Stationary", "Straight", "Turning", "train s",
    ]);
    let mut summary: Vec<(Method, f64, f64)> = Vec::new(); // (m, nll, turning)

    for method in Method::ALL {
        let mut nlls = Vec::new();
        let mut stationary = Vec::new();
        let mut straight = Vec::new();
        let mut turning = Vec::new();
        let mut train_secs = 0.0;

        for seed in 0..n_seeds as u64 {
            let mut model = ModelHandle::init(Arc::clone(&engine), method, seed as i32)?;
            let mut trainer =
                Trainer::new(cfg.model.clone(), cfg.sim.clone(), n_examples, seed);
            let report = trainer.run(&mut model, steps)?;
            train_secs += report.wall_secs;

            let mut row = TableOneRow::default();
            rollout.evaluate(&model, &eval_seeds, n_samples, &mut row)?;
            nlls.push(row.nll());
            stationary.push(row.min_ade(TrajectoryClass::Stationary));
            straight.push(row.min_ade(TrajectoryClass::Straight));
            turning.push(row.min_ade(TrajectoryClass::Turning));
            eprintln!(
                "  {} seed {}: NLL {:.3}, minADE T {:.2}",
                method.name(),
                seed,
                row.nll(),
                row.min_ade(TrajectoryClass::Turning)
            );
        }

        let (nll, _) = mean_std(&nlls);
        let (st, _) = mean_std(&stationary);
        let (sr, _) = mean_std(&straight);
        let (tu, _) = mean_std(&turning);
        table.row(vec![
            method.display().into(),
            format!("{nll:.3}"),
            format!("{st:.2}"),
            format!("{sr:.2}"),
            format!("{tu:.2}"),
            format!("{train_secs:.0}"),
        ]);
        summary.push((method, nll, tu));
        record_row(
            "table1_agent_sim",
            Json::obj(vec![
                ("method", Json::Str(method.name().into())),
                ("nll", Json::Num(nll)),
                ("minade_stationary", Json::Num(st)),
                ("minade_straight", Json::Num(sr)),
                ("minade_turning", Json::Num(tu)),
                ("steps", Json::Num(steps as f64)),
                ("seeds", Json::Num(n_seeds as f64)),
            ]),
        );
    }

    println!();
    table.print();

    // shape commentary vs the paper
    let get = |m: Method| summary.iter().find(|(mm, _, _)| *mm == m).unwrap();
    let abs = get(Method::Abs);
    let fourier = get(Method::Se2Fourier);
    println!("\n# paper-shape notes:");
    println!(
        "- relative methods beat absolute positions on NLL: {} (abs {:.3} vs se2fourier {:.3})",
        if fourier.1 <= abs.1 { "yes" } else { "NOT REPRODUCED at this scale" },
        abs.1,
        fourier.1
    );
    let rope = get(Method::Rope2d);
    println!(
        "- se2fourier vs rope2d on turning minADE: {:.2} vs {:.2} ({})",
        fourier.2,
        rope.2,
        if fourier.2 <= rope.2 { "se2fourier better — matches paper" } else { "rope2d better at this scale" }
    );
    println!("\ntable1_agent_sim OK");
    Ok(())
}
