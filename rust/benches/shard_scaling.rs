//! Aggregate rollout throughput vs worker-shard count (ISSUE 3
//! acceptance): the same mixed-family workload is served end to end
//! through the sharded coordinator — shard router, per-shard admission
//! queues + continuous step loops, per-shard KV-cache pools over the
//! shared map registry, rollout scheduler — at 1, 2 and 4 workers, and
//! the aggregate scenes/s must grow with the worker count (strictly,
//! 1 -> 4, on a multi-core host).
//!
//! The backend is the artifact-free [`SyntheticDecoder`] with a tuned
//! `work_per_token`, emulating a model-latency-bound decode so the bench
//! runs (and scales) in the default stub-runtime build.
//!
//! A second section (ISSUE 10 acceptance) runs the same workload through
//! the **multi-process** path: worker shards as real child processes
//! behind [`ProcServer`]'s wire protocol.  Aggregate scenes/s must grow
//! from 1 to 4 worker processes on a multi-core host.
//!
//! Run: `cargo bench --bench shard_scaling`

use std::sync::Arc;
use std::time::Instant;

use se2attn::benchlib::{record_row, Table};
use se2attn::config::{Method, ModelConfig, ProcConfig, SimConfig, SystemConfig};
use se2attn::coordinator::{
    AdmissionConfig, Backend, BackendFactory, CacheConfig, ProcServer, RolloutRequest, Router,
    ServeConfig, Server, SyntheticDecoder,
};
use se2attn::jsonio::Json;
use se2attn::sim::MixGenerator;

const METHOD: Method = Method::Se2Fourier;
const SCENES: usize = 48;
const SAMPLES: usize = 2;
/// Extra hash rounds per token emulating model latency (decode-bound).
const WORK_PER_TOKEN: usize = 800;

fn model_config() -> ModelConfig {
    ModelConfig::synthetic()
}

fn factory() -> BackendFactory {
    Arc::new(|_shard: usize| -> anyhow::Result<Backend> {
        let mut backend: Backend = Router::new();
        backend.deploy(
            METHOD,
            Box::new(SyntheticDecoder::with_work(
                model_config().n_actions,
                WORK_PER_TOKEN,
            )),
        );
        Ok(backend)
    })
}

/// Serve the whole mixed-family workload once; returns (wall s, scenes/s).
fn run(workers: usize) -> (f64, f64) {
    let cfg = SystemConfig {
        artifact_dir: std::path::PathBuf::from("artifacts-not-needed"),
        model: model_config(),
        sim: SimConfig::default(),
        threads: workers,
    };
    let sim = cfg.sim.clone();
    let server = Server::start_with_backend(
        cfg,
        vec![METHOD],
        ServeConfig {
            workers,
            admission: AdmissionConfig {
                max_queue: 4096,
                ..AdmissionConfig::default()
            },
            cache: CacheConfig::default(),
            kernel: se2attn::attention::kernel::KernelConfig::default(),
            ..ServeConfig::default()
        },
        factory(),
    )
    .expect("server start");

    let mix = se2attn::config::scenario_mix("mixed", "").expect("mix");
    let gen = MixGenerator::new(sim.clone(), mix);
    let t0 = Instant::now();
    let pending: Vec<_> = (0..SCENES)
        .map(|i| {
            let scenario = gen.generate(3000 + i as u64);
            server.submit(
                METHOD,
                RolloutRequest {
                    scenario,
                    t0: sim.history_steps - 1,
                    n_samples: SAMPLES,
                    temperature: 1.0,
                    seed: i as i32,
                },
            )
        })
        .collect();
    for rx in pending {
        rx.recv().expect("shard alive").expect("rollout ok");
    }
    let wall = t0.elapsed().as_secs_f64();
    (wall, SCENES as f64 / wall)
}

/// Same workload through `workers` real child processes speaking the
/// wire protocol (the `simulate --worker-procs` path); returns
/// (wall s, scenes/s).  Wall time includes envelope/response
/// serialization — the protocol overhead the multi-process gate prices.
fn run_procs(workers: usize) -> (f64, f64) {
    let sim = SimConfig::default();
    let worker_cmd = vec![
        env!("CARGO_BIN_EXE_se2-attention").to_string(),
        "worker".to_string(),
        "--methods".to_string(),
        METHOD.name().to_string(),
        "--synthetic-work".to_string(),
        WORK_PER_TOKEN.to_string(),
    ];
    let server = ProcServer::start(
        workers,
        ProcConfig::default(),
        AdmissionConfig {
            max_queue: 4096,
            ..AdmissionConfig::default()
        },
        worker_cmd,
    )
    .expect("proc server start");

    let mix = se2attn::config::scenario_mix("mixed", "").expect("mix");
    let gen = MixGenerator::new(sim.clone(), mix);
    let t0 = Instant::now();
    let pending: Vec<_> = (0..SCENES)
        .map(|i| {
            let scenario = gen.generate(3000 + i as u64);
            server.submit(
                METHOD,
                RolloutRequest {
                    scenario,
                    t0: sim.history_steps - 1,
                    n_samples: SAMPLES,
                    temperature: 1.0,
                    seed: i as i32,
                },
            )
        })
        .collect();
    for rx in pending {
        rx.recv().expect("coordinator alive").expect("rollout ok");
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();
    (wall, SCENES as f64 / wall)
}

fn main() {
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    println!(
        "\n== shard scaling: {SCENES} mixed-family scenes x {SAMPLES} samples, \
         decode-bound synthetic backend ({cores} cores) =="
    );
    // warm one pass so allocator/page-cache effects don't bias workers=1
    let _ = run(1);

    let mut table = Table::new(&["workers", "wall s", "scenes/s", "speedup vs 1"]);
    let mut throughput = Vec::new();
    for workers in [1usize, 2, 4] {
        let (wall, tput) = run(workers);
        throughput.push((workers, tput));
        let speedup = tput / throughput[0].1;
        table.row(vec![
            workers.to_string(),
            format!("{wall:.2}"),
            format!("{tput:.1}"),
            format!("{speedup:.2}x"),
        ]);
        record_row(
            "shard_scaling",
            Json::obj(vec![
                ("workers", Json::Num(workers as f64)),
                ("scenes", Json::Num(SCENES as f64)),
                ("samples", Json::Num(SAMPLES as f64)),
                ("wall_s", Json::Num(wall)),
                ("scenes_per_s", Json::Num(tput)),
            ]),
        );
    }
    table.print();

    let strictly_increasing = throughput.windows(2).all(|w| w[1].1 > w[0].1);
    if strictly_increasing {
        println!("strictly increasing aggregate throughput 1 -> 4 workers: PASS");
    } else if cores < 4 {
        println!(
            "throughput not strictly increasing — expected on a {cores}-core host; \
             re-run on >=4 cores for the acceptance check"
        );
    } else {
        println!("strictly increasing aggregate throughput 1 -> 4 workers: FAIL");
        std::process::exit(1);
    }

    println!(
        "\n== multi-process scaling: same workload through worker *processes* \
         (wire protocol + session codec on the path) =="
    );
    let mut table = Table::new(&["worker procs", "wall s", "scenes/s", "speedup vs 1"]);
    let mut proc_tput = Vec::new();
    for workers in [1usize, 2, 4] {
        let (wall, tput) = run_procs(workers);
        proc_tput.push((workers, tput));
        let speedup = tput / proc_tput[0].1;
        table.row(vec![
            workers.to_string(),
            format!("{wall:.2}"),
            format!("{tput:.1}"),
            format!("{speedup:.2}x"),
        ]);
        record_row(
            "proc_scaling",
            Json::obj(vec![
                ("worker_procs", Json::Num(workers as f64)),
                ("scenes", Json::Num(SCENES as f64)),
                ("samples", Json::Num(SAMPLES as f64)),
                ("wall_s", Json::Num(wall)),
                ("scenes_per_s", Json::Num(tput)),
            ]),
        );
    }
    table.print();

    let first = proc_tput[0].1;
    let last = proc_tput.last().expect("proc rows").1;
    if last > first {
        println!("aggregate throughput grows 1 -> 4 worker processes: PASS");
    } else if cores < 4 {
        println!(
            "no cross-process growth — expected on a {cores}-core host; \
             re-run on >=4 cores for the acceptance check"
        );
    } else {
        println!("aggregate throughput grows 1 -> 4 worker processes: FAIL");
        std::process::exit(1);
    }
}
