//! Approximation explorer: interactive-style CLI over the SE(2) Fourier
//! machinery — sweeps radius x basis size (Fig. 3's axes), prints ASCII
//! plots of the target function vs its truncated series (Fig. 4's view),
//! and verifies the factorization identity phi_q(p_n) phi_k(p_m) ~=
//! phi(p_{n->m}) on random poses.
//!
//! Run: `cargo run --release --example approximation_explorer`

use se2attn::fourier::{
    approximation_error, coefficients, reconstruct, u_x, Axis, BF16_EPS, FP16_EPS,
};
use se2attn::geometry::Pose;
use se2attn::prng::Rng;

fn main() {
    println!("== SE(2) Fourier approximation explorer ==\n");

    // ---- radius x basis sweep (Fig. 3's content, coarse) ----------------
    println!("mean spectral-norm error ||phi(rel) - phi_q phi_k||_2");
    println!("(256 random pose pairs per cell; fp16 eps {FP16_EPS:.1e}, bf16 eps {BF16_EPS:.1e})\n");
    let radii = [0.5, 1.0, 2.0, 4.0, 8.0];
    let basis = [6usize, 12, 18, 28, 40];
    print!("{:>8}", "r \\ F");
    for f in basis {
        print!("{f:>10}");
    }
    println!();
    let mut rng = Rng::new(1);
    for r in radii {
        print!("{r:>8.1}");
        for f in basis {
            let mut total = 0.0;
            let trials = 256;
            for _ in 0..trials {
                let psi = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
                let pm = Pose::new(r * psi.cos(), r * psi.sin(), rng.range(-3.1, 3.1));
                let pn = Pose::new(0.0, 0.0, rng.range(-3.1, 3.1));
                total += approximation_error(&pn, &pm, f);
            }
            print!("{:>10.1e}", total / trials as f64);
        }
        println!();
    }

    // ---- Fig. 4-style ASCII plot ----------------------------------------
    println!("\ntarget cos(u_m^(x)(theta)) vs Fourier approximations");
    for (x, y) in [(1.0f64, 0.0f64), (6.0, -4.0)] {
        let r = (x * x + y * y).sqrt();
        println!("\nkey position ({x}, {y})  |p| = {r:.1}");
        let width = 64;
        for f in [4usize, 8, 16, 28] {
            let (gamma, _) = coefficients(x, y, f, Axis::X);
            let mut err: f64 = 0.0;
            let mut line = String::new();
            for i in 0..width {
                let t = -std::f64::consts::PI
                    + std::f64::consts::TAU * i as f64 / width as f64;
                let exact = u_x(x, y, t).cos();
                let approx = reconstruct(&gamma, t);
                err = err.max((exact - approx).abs());
                // render the approximation as a height-5 strip
                let level = ((approx + 1.0) / 2.0 * 4.0).round() as i64;
                line.push(match level.clamp(0, 4) {
                    0 => '_',
                    1 => '.',
                    2 => '-',
                    3 => '=',
                    _ => '#',
                });
            }
            println!("  F={f:<3} max err {err:>8.1e}  {line}");
        }
    }

    // ---- factorization identity spot check ------------------------------
    println!("\nfactorization identity on 1000 random pose pairs (F=28, |p|<=4):");
    let mut worst: f64 = 0.0;
    for _ in 0..1000 {
        let pn = Pose::new(rng.range(-2.8, 2.8), rng.range(-2.8, 2.8), rng.range(-3.1, 3.1));
        let pm = Pose::new(rng.range(-2.8, 2.8), rng.range(-2.8, 2.8), rng.range(-3.1, 3.1));
        worst = worst.max(approximation_error(&pn, &pm, 28));
    }
    println!("worst error {worst:.2e}  (paper: <1e-3 achievable — {})",
        if worst < 1e-3 { "CONFIRMED" } else { "not met at these radii" });
    println!("\napproximation_explorer OK");
}
