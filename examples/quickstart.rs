//! Quickstart: load the SE(2) Fourier attention artifact, run it on a toy
//! scene, and numerically demonstrate the paper's invariance claim
//! (Fig. 1): shifting/rotating the global frame leaves the outputs
//! (approximately) unchanged, while the non-invariant baselines move.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use se2attn::config::{Method, SystemConfig};
use se2attn::geometry::Pose;
use se2attn::prng::Rng;
use se2attn::runtime::{Engine, HostTensor};

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn main() -> Result<()> {
    let cfg = SystemConfig::load("artifacts")?;
    let engine = Engine::cpu(&cfg.artifact_dir)?;
    println!("== quickstart: SE(2) invariant attention on {} ==\n", engine.platform());

    let n = cfg.model.n_tokens;
    let dh = cfg.model.head_dim;
    let mut rng = Rng::new(7);

    // a toy scene: tokens scattered in the model's position band
    let q: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..n * dh).map(|_| rng.normal() as f32).collect();
    let poses: Vec<Pose> = (0..n)
        .map(|_| {
            Pose::new(
                rng.range(-1.5, 1.5),
                rng.range(-1.5, 1.5),
                rng.range(-3.1, 3.1),
            )
        })
        .collect();
    let tq: Vec<i32> = (0..n).map(|i| (i / 8) as i32).collect();

    // a global frame change z (robot moved + turned; Fig. 1's premise)
    let z = Pose::new(0.9, -0.6, 1.1);
    let zi = z.inverse();
    let shifted: Vec<Pose> = poses.iter().map(|p| zi.compose(p)).collect();

    let pose_tensor = |ps: &[Pose]| {
        let flat: Vec<f32> = ps
            .iter()
            .flat_map(|p| [p.x as f32, p.y as f32, p.theta as f32])
            .collect();
        HostTensor::f32(vec![n, 3], flat)
    };

    println!("running the AOT attention artifacts (Pallas flash SDPA inside):");
    println!("{:<24} {:>16} {:>12}", "method", "|Δout| frame-shift", "invariant?");
    for method in Method::ALL {
        let artifact = engine.load(&format!("attn_{}", method.name()))?;
        let run = |ps: &[Pose]| -> Result<Vec<f32>> {
            let out = artifact.execute(&[
                HostTensor::f32(vec![n, dh], q.clone()),
                HostTensor::f32(vec![n, dh], k.clone()),
                HostTensor::f32(vec![n, dh], v.clone()),
                pose_tensor(ps),
                HostTensor::i32(vec![n], tq.clone()),
            ])?;
            Ok(out[0].as_f32()?.to_vec())
        };
        let o1 = run(&poses)?;
        let o2 = run(&shifted)?;
        let d = max_abs_diff(&o1, &o2);
        let invariant = d < 0.05;
        println!(
            "{:<24} {:>16.2e} {:>12}",
            method.display(),
            d,
            if invariant { "yes" } else { "NO" }
        );
    }

    println!(
        "\nExpected: only the SE(2) methods are invariant; 'abs' ignores pose\n\
         entirely in this artifact (plain SDPA) and 2D RoPE breaks under the\n\
         rotation component (paper Fig. 1b)."
    );

    // cross-check the artifact against the native quadratic oracle
    println!("\ncross-checking AOT linear path vs native quadratic Algorithm 1...");
    let artifact = engine.load("attn_se2fourier")?;
    let out = artifact.execute(&[
        HostTensor::f32(vec![n, dh], q.clone()),
        HostTensor::f32(vec![n, dh], k.clone()),
        HostTensor::f32(vec![n, dh], v.clone()),
        pose_tensor(&poses),
        HostTensor::i32(vec![n], tq.clone()),
    ])?;
    let got = out[0].as_f32()?;
    let problem = se2attn::attention::AttnProblem {
        method: Method::Se2Fourier,
        d: dh,
        fourier_f: cfg.model.fourier_f,
        scales: &cfg.model.spatial_scales,
        q: &q,
        k: &k,
        v: &v,
        pose_q: &poses,
        pose_k: &poses,
        tq: &tq,
        tk: &tq,
    };
    let oracle = se2attn::attention::quadratic::attention(&problem);
    let err = max_abs_diff(got, &oracle.out);
    println!(
        "max |AOT linear - quadratic oracle| = {err:.2e}  (F={}, fp16 eps = {:.2e})",
        cfg.model.fourier_f,
        se2attn::fourier::FP16_EPS
    );
    assert!(err < 0.15, "linear path diverged from the oracle");
    println!("\nquickstart OK");
    Ok(())
}
