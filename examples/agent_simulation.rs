//! Serving driver: batched rollout requests through the full coordinator
//! (router -> admission queue -> continuous step scheduler -> PJRT
//! decode), with a latency / throughput report — the "multi-agent
//! behavior simulation" workload the paper's introduction motivates.
//!
//! Run: `cargo run --release --example agent_simulation [scenes] [samples]`

use anyhow::Result;

use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{AdmissionConfig, RolloutRequest, ServeConfig, Server};
use se2attn::sim::ScenarioGenerator;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scenes: usize = args.first().map_or(12, |s| s.parse().unwrap());
    let samples: usize = args.get(1).map_or(4, |s| s.parse().unwrap());
    let workers: usize = args.get(2).map_or(0, |s| s.parse().unwrap());

    let cfg = SystemConfig::load("artifacts")?;
    let method = Method::Se2Fourier;
    println!(
        "== agent_simulation: serving {scenes} scenes x {samples} samples with {} ==",
        method.display()
    );

    let t_start = std::time::Instant::now();
    let serve = ServeConfig {
        admission: AdmissionConfig {
            max_queue: 64,
            max_live_sessions: 4,
            ..AdmissionConfig::default()
        },
        ..ServeConfig::with_workers(workers)
    };
    let server = Server::start(cfg.clone(), vec![method], 0, serve)?;
    println!(
        "server up in {:.1}s on {} shard(s) (artifact compile included)",
        t_start.elapsed().as_secs_f64(),
        server.n_shards()
    );

    let gen = ScenarioGenerator::new(cfg.sim.clone());
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..scenes {
        let scenario = gen.generate(500 + i as u64);
        pending.push(server.submit(
            method,
            RolloutRequest {
                scenario,
                t0: cfg.sim.history_steps - 1,
                n_samples: samples,
                temperature: 1.0,
                seed: i as i32,
            },
        ));
    }

    let mut per_scene_ade = Vec::new();
    let mut decode_ms = Vec::new();
    for (i, rx) in pending.into_iter().enumerate() {
        let res = rx.recv().expect("server alive")?;
        let mean_ade: f64 =
            res.min_ade.iter().sum::<f64>() / res.min_ade.len() as f64;
        per_scene_ade.push(mean_ade);
        decode_ms.push(res.decode_ms);
        println!(
            "scene {i:>3}: minADE(mean over {} agents) {:>6.2} m, decode {:.1} ms/step",
            res.min_ade.len(),
            mean_ade,
            res.decode_ms
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let (ade_mean, ade_std) = se2attn::metrics::mean_std(&per_scene_ade);
    let (dec_mean, _) = se2attn::metrics::mean_std(&decode_ms);

    println!("\n-- serving report --");
    println!("scenes          : {scenes} (x{samples} samples, {} future steps)", cfg.sim.future_steps);
    println!("wall time       : {wall:.2} s");
    println!("throughput      : {:.2} scenes/s ({:.1} agent-futures/s)",
        scenes as f64 / wall,
        (scenes * samples * cfg.sim.n_agents) as f64 / wall);
    println!("decode step     : {dec_mean:.1} ms mean");
    println!("minADE          : {ade_mean:.2} ± {ade_std:.2} m (untrained weights — see train_agents)");
    println!("server          : {}", server.stats.summary());
    println!("\nagent_simulation OK");
    Ok(())
}
