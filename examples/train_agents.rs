//! End-to-end training driver (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Generates a synthetic scenario dataset, trains the agent-simulation
//! transformer with SE(2) Fourier attention for a few hundred steps via the
//! AOT train_step artifact (Adam state threaded through PJRT), logs the
//! loss curve, then evaluates NLL + minADE with sampled rollouts.
//!
//! Run: `cargo run --release --example train_agents [steps] [examples] [method]`

use std::sync::Arc;

use anyhow::Result;

use se2attn::config::{Method, SystemConfig};
use se2attn::coordinator::{ModelHandle, RolloutEngine, Trainer};
use se2attn::metrics::TableOneRow;
use se2attn::runtime::Engine;
use se2attn::sim::TrajectoryClass;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().map_or(300, |s| s.parse().unwrap());
    let n_examples: usize = args.get(1).map_or(512, |s| s.parse().unwrap());
    let method = Method::parse(args.get(2).map_or("se2fourier", String::as_str))?;

    let cfg = SystemConfig::load("artifacts")?;
    let engine = Arc::new(Engine::cpu(&cfg.artifact_dir)?);
    let mut model = ModelHandle::init(Arc::clone(&engine), method, 0)?;
    println!(
        "== train_agents: {} | {} weights | {} steps x batch {} | {} examples ==",
        method.display(),
        model.n_weights(),
        steps,
        cfg.model.batch_size,
        n_examples
    );

    // ---- dataset + training -------------------------------------------
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg.model.clone(), cfg.sim.clone(), n_examples, 0);
    println!(
        "dataset: {} train / {} val examples ({:.1}s to generate)",
        trainer.loader.train.len(),
        trainer.loader.val.len(),
        t0.elapsed().as_secs_f64()
    );

    let report = trainer.run(&mut model, steps)?;
    println!("\nloss curve:");
    for (step, loss) in &report.loss_curve {
        let bar = "#".repeat((loss * 12.0) as usize);
        println!("  step {step:>5}  {loss:7.4}  {bar}");
    }
    println!(
        "\ntrained {} steps in {:.1}s ({:.2} steps/s, {:.1} examples/s)",
        report.steps,
        report.wall_secs,
        report.steps as f64 / report.wall_secs,
        report.examples_seen as f64 / report.wall_secs
    );
    println!("validation NLL: {:.4}", report.final_val_loss);
    let first = report.loss_curve.first().unwrap().1;
    let last = report.loss_curve.last().unwrap().1;
    assert!(
        last < first,
        "training must reduce loss ({first} -> {last})"
    );

    // ---- rollout evaluation --------------------------------------------
    println!("\nevaluating rollouts (minADE over sampled futures)...");
    let rollout = RolloutEngine::new(cfg.model.clone(), cfg.sim.clone());
    let mut row = TableOneRow::default();
    let eval_seeds: Vec<u64> = (10_000..10_006).collect();
    rollout.evaluate(&model, &eval_seeds, 8, &mut row)?;
    println!("NLL {:.3}", row.nll());
    for class in [
        TrajectoryClass::Stationary,
        TrajectoryClass::Straight,
        TrajectoryClass::Turning,
    ] {
        println!(
            "minADE[{:<10}] {:>6.2} m  (n={})",
            class.name(),
            row.min_ade(class),
            row.count(class)
        );
    }
    println!("\ntrain_agents OK — record this run in EXPERIMENTS.md §E2E");
    Ok(())
}
